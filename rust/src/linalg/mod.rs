//! Dense linear algebra substrate.
//!
//! The baselines (the wrapper Algorithm 1 and the low-rank updated LS-SVM
//! Algorithm 2) and the RLS closed forms (eqs. 3/4 of the paper) need
//! general dense solves and symmetric inverses. No external BLAS/LAPACK is
//! available offline, so this module implements the required kernels from
//! scratch: row-major [`Matrix`], matrix products, Cholesky and
//! partial-pivoting LU factorizations, triangular solves, symmetric
//! inverse, and the Sherman–Morrison rank-1 inverse update the paper's
//! eq. (10) is built on.

mod cholesky;
mod lu;

pub use cholesky::Cholesky;
pub use lu::{inverse, Lu};

use crate::data::storage::ReadMap;

/// Backing buffer of a [`Matrix`]: an owned RAM vector (the default) or
/// a shared read-only file mapping. Mutable access to a mapped matrix
/// transparently promotes the buffer to RAM (copy-on-write), so every
/// existing `Matrix` consumer works unchanged on mapped data.
#[derive(Clone, Debug)]
enum Buf {
    Ram(Vec<f64>),
    Mapped(ReadMap),
}

impl Buf {
    #[inline]
    fn as_slice(&self) -> &[f64] {
        match self {
            Buf::Ram(v) => v,
            Buf::Mapped(m) => m.as_slice(),
        }
    }

    /// Mutable view, promoting a mapped buffer to RAM first.
    #[inline]
    fn make_mut(&mut self) -> &mut [f64] {
        if let Buf::Mapped(m) = self {
            *self = Buf::Ram(m.as_slice().to_vec());
        }
        match self {
            Buf::Ram(v) => v,
            Buf::Mapped(_) => unreachable!("promoted above"),
        }
    }
}

/// Dense row-major matrix of `f64`.
#[derive(Clone, Debug)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Buf,
}

impl PartialEq for Matrix {
    fn eq(&self, other: &Matrix) -> bool {
        self.rows == other.rows
            && self.cols == other.cols
            && self.as_slice() == other.as_slice()
    }
}

impl Matrix {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: Buf::Ram(vec![0.0; rows * cols]) }
    }

    /// Identity.
    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Matrix from a row-major vector.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Matrix { rows, cols, data: Buf::Ram(data) }
    }

    /// Matrix over a shared read-only file mapping (see
    /// [`crate::data::storage::ReadMap`]). Read access streams straight
    /// from the mapping; the first mutable access copies to RAM.
    pub fn from_mapped(rows: usize, cols: usize, map: ReadMap) -> Self {
        assert_eq!(map.len(), rows * cols, "shape/data mismatch");
        Matrix { rows, cols, data: Buf::Mapped(map) }
    }

    /// Matrix from nested rows (convenient in tests).
    pub fn from_rows(rows: &[&[f64]]) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, |row| row.len());
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Matrix { rows: r, cols: c, data: Buf::Ram(data) }
    }

    /// Number of rows (features, in the crate's feature-major layout).
    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns (examples, in the crate's feature-major layout).
    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Contiguous row slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data.as_slice()[i * self.cols..(i + 1) * self.cols]
    }

    /// Mutable contiguous row slice.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        let (cols, i0) = (self.cols, i * self.cols);
        &mut self.data.make_mut()[i0..i0 + cols]
    }

    /// Column copied out (rows are the contiguous axis).
    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self[(i, j)]).collect()
    }

    /// Underlying row-major storage.
    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        self.data.as_slice()
    }

    /// Underlying row-major storage, mutably (promotes a mapped buffer
    /// to RAM first).
    #[inline]
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        self.data.make_mut()
    }

    /// Transposed copy.
    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// `self * other`.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Matrix::zeros(self.rows, other.cols);
        // ikj loop order: both inner accesses are row-contiguous.
        for i in 0..self.rows {
            for k in 0..self.cols {
                let aik = self[(i, k)];
                if aik == 0.0 {
                    continue;
                }
                let brow = other.row(k);
                let orow = out.row_mut(i);
                for (o, &b) in orow.iter_mut().zip(brow) {
                    *o += aik * b;
                }
            }
        }
        out
    }

    /// `self * v` for a column vector `v`.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, v.len(), "matvec shape mismatch");
        (0..self.rows).map(|i| dot(self.row(i), v)).collect()
    }

    /// `selfᵀ * v` without forming the transpose.
    pub fn tr_matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.rows, v.len(), "tr_matvec shape mismatch");
        let mut out = vec![0.0; self.cols];
        for i in 0..self.rows {
            let vi = v[i];
            if vi == 0.0 {
                continue;
            }
            for (o, &x) in out.iter_mut().zip(self.row(i)) {
                *o += vi * x;
            }
        }
        out
    }

    /// Gram matrix `self * selfᵀ` (symmetric, upper computed + mirrored).
    pub fn gram(&self) -> Matrix {
        let mut g = Matrix::zeros(self.rows, self.rows);
        for i in 0..self.rows {
            for j in i..self.rows {
                let v = dot(self.row(i), self.row(j));
                g[(i, j)] = v;
                g[(j, i)] = v;
            }
        }
        g
    }

    /// `selfᵀ * self` (the kernel matrix K of eq. 6 when self = X_S).
    pub fn gram_t(&self) -> Matrix {
        let t = self.transpose();
        t.gram()
    }

    /// Add `lambda` to the diagonal in place.
    pub fn add_diag(&mut self, lambda: f64) {
        let n = self.rows.min(self.cols);
        for i in 0..n {
            self[(i, i)] += lambda;
        }
    }

    /// Submatrix with the given rows (copies).
    pub fn select_rows(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(idx.len(), self.cols);
        for (r, &i) in idx.iter().enumerate() {
            out.row_mut(r).copy_from_slice(self.row(i));
        }
        out
    }

    /// Submatrix with the given columns (copies).
    pub fn select_cols(&self, idx: &[usize]) -> Matrix {
        let mut out = Matrix::zeros(self.rows, idx.len());
        for i in 0..self.rows {
            for (c, &j) in idx.iter().enumerate() {
                out[(i, c)] = self[(i, j)];
            }
        }
        out
    }

    /// Max |a_ij - b_ij|.
    pub fn max_abs_diff(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.as_slice()
            .iter()
            .zip(other.as_slice())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data.as_slice()[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        let idx = i * self.cols + j;
        &mut self.data.make_mut()[idx]
    }
}

/// Dot product (manually 4-way unrolled so LLVM autovectorizes).
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        s += a[i] * b[i];
    }
    s
}

/// [`dot`] evaluated tile-by-tile with the four partial sums carried
/// across tiles. For any `tile` that is a positive multiple of 4 this
/// performs literally the same multiply/add sequence as [`dot`] — the
/// quad grouping is unchanged, only the loop is split — so the result
/// is bit-identical. This is the determinism argument behind the
/// LLC-tiled out-of-core kernels (ARCHITECTURE.md §Data backends),
/// stated as a reusable primitive.
///
/// ```
/// use greedy_rls::linalg::{dot, dot_tiled};
///
/// let a: Vec<f64> = (0..100).map(|i| (i as f64).sin()).collect();
/// let b: Vec<f64> = (0..100).map(|i| (i as f64).cos()).collect();
/// assert_eq!(dot_tiled(&a, &b, 16).to_bits(), dot(&a, &b).to_bits());
/// # anyhow::Ok(())
/// ```
#[inline]
pub fn dot_tiled(a: &[f64], b: &[f64], tile: usize) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    debug_assert!(tile > 0 && tile % 4 == 0, "tile must be a multiple of 4");
    let n = a.len();
    let quads = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0, 0.0);
    let tile_q = tile / 4;
    let mut q0 = 0;
    while q0 < quads {
        let q1 = (q0 + tile_q).min(quads);
        for c in q0..q1 {
            let i = c * 4;
            s0 += a[i] * b[i];
            s1 += a[i + 1] * b[i + 1];
            s2 += a[i + 2] * b[i + 2];
            s3 += a[i + 3] * b[i + 3];
        }
        q0 = q1;
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in quads * 4..n {
        s += a[i] * b[i];
    }
    s
}

/// `y += alpha * x`.
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

/// Euclidean norm.
pub fn norm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

/// Symmetric positive-definite inverse via Cholesky (used for G = (K+λI)⁻¹).
pub fn spd_inverse(a: &Matrix) -> Option<Matrix> {
    let chol = Cholesky::factor(a)?;
    let n = a.rows();
    let mut inv = Matrix::zeros(n, n);
    let mut e = vec![0.0; n];
    for j in 0..n {
        e[j] = 1.0;
        let col = chol.solve(&e);
        for i in 0..n {
            inv[(i, j)] = col[i];
        }
        e[j] = 0.0;
    }
    Some(inv)
}

/// Sherman–Morrison: given `Ainv = A⁻¹`, return `(A + v vᵀ)⁻¹`
/// = Ainv − (Ainv v)(vᵀ Ainv) / (1 + vᵀ Ainv v)  — eq. (10) of the paper.
pub fn sherman_morrison_update(ainv: &Matrix, v: &[f64]) -> Matrix {
    let n = ainv.rows();
    assert_eq!(n, v.len());
    let gv = ainv.matvec(v); // A⁻¹v (symmetric ⇒ also vᵀA⁻¹)
    let denom = 1.0 + dot(v, &gv);
    let mut out = ainv.clone();
    for i in 0..n {
        let ui = gv[i] / denom;
        let row = out.row_mut(i);
        for (j, r) in row.iter_mut().enumerate() {
            *r -= ui * gv[j];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn random_matrix(rng: &mut Pcg64, r: usize, c: usize) -> Matrix {
        Matrix::from_vec(r, c, (0..r * c).map(|_| rng.normal()).collect())
    }

    fn random_spd(rng: &mut Pcg64, n: usize) -> Matrix {
        let a = random_matrix(rng, n, n + 2);
        let mut g = a.gram();
        g.add_diag(0.5);
        g
    }

    #[test]
    fn index_roundtrip() {
        let mut m = Matrix::zeros(3, 4);
        m[(1, 2)] = 5.0;
        assert_eq!(m[(1, 2)], 5.0);
        assert_eq!(m.row(1)[2], 5.0);
        assert_eq!(m.col(2)[1], 5.0);
    }

    #[test]
    fn matmul_known() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = Matrix::from_rows(&[&[5.0, 6.0], &[7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c, Matrix::from_rows(&[&[19.0, 22.0], &[43.0, 50.0]]));
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Pcg64::seeded(1);
        let a = random_matrix(&mut rng, 5, 5);
        let i = Matrix::identity(5);
        assert!(a.matmul(&i).max_abs_diff(&a) < 1e-15);
        assert!(i.matmul(&a).max_abs_diff(&a) < 1e-15);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Pcg64::seeded(2);
        let a = random_matrix(&mut rng, 4, 7);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Pcg64::seeded(3);
        let a = random_matrix(&mut rng, 6, 4);
        let v: Vec<f64> = (0..4).map(|_| rng.normal()).collect();
        let vm = Matrix::from_vec(4, 1, v.clone());
        let want = a.matmul(&vm);
        let got = a.matvec(&v);
        for i in 0..6 {
            assert!((got[i] - want[(i, 0)]).abs() < 1e-12);
        }
    }

    #[test]
    fn tr_matvec_matches_transpose() {
        let mut rng = Pcg64::seeded(4);
        let a = random_matrix(&mut rng, 6, 4);
        let v: Vec<f64> = (0..6).map(|_| rng.normal()).collect();
        let want = a.transpose().matvec(&v);
        let got = a.tr_matvec(&v);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-12);
        }
    }

    #[test]
    fn gram_is_symmetric_psd_diag() {
        let mut rng = Pcg64::seeded(5);
        let a = random_matrix(&mut rng, 5, 8);
        let g = a.gram();
        for i in 0..5 {
            assert!(g[(i, i)] >= 0.0);
            for j in 0..5 {
                assert!((g[(i, j)] - g[(j, i)]).abs() < 1e-14);
            }
        }
    }

    #[test]
    fn select_rows_cols() {
        let a = Matrix::from_rows(&[
            &[1.0, 2.0, 3.0],
            &[4.0, 5.0, 6.0],
            &[7.0, 8.0, 9.0],
        ]);
        let r = a.select_rows(&[2, 0]);
        assert_eq!(r, Matrix::from_rows(&[&[7.0, 8.0, 9.0], &[1.0, 2.0, 3.0]]));
        let c = a.select_cols(&[1]);
        assert_eq!(c, Matrix::from_rows(&[&[2.0], &[5.0], &[8.0]]));
    }

    #[test]
    fn dot_tiled_matches_dot_bitwise() {
        let mut rng = Pcg64::seeded(11);
        for len in [0, 1, 3, 4, 5, 17, 64, 101, 1000] {
            let a: Vec<f64> = (0..len).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..len).map(|_| rng.normal()).collect();
            let want = dot(&a, &b).to_bits();
            for tile in [4, 8, 16, 40, 1024] {
                assert_eq!(
                    dot_tiled(&a, &b, tile).to_bits(),
                    want,
                    "len {len} tile {tile}"
                );
            }
        }
    }

    #[test]
    fn dot_unroll_matches_naive() {
        let mut rng = Pcg64::seeded(6);
        for len in [0, 1, 3, 4, 5, 17, 64, 101] {
            let a: Vec<f64> = (0..len).map(|_| rng.normal()).collect();
            let b: Vec<f64> = (0..len).map(|_| rng.normal()).collect();
            let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-10, "len {len}");
        }
    }

    #[test]
    fn spd_inverse_roundtrip() {
        let mut rng = Pcg64::seeded(7);
        let a = random_spd(&mut rng, 8);
        let inv = spd_inverse(&a).unwrap();
        let eye = a.matmul(&inv);
        assert!(eye.max_abs_diff(&Matrix::identity(8)) < 1e-9);
    }

    #[test]
    fn sherman_morrison_matches_reinversion() {
        let mut rng = Pcg64::seeded(8);
        let mut a = random_spd(&mut rng, 6);
        let ainv = spd_inverse(&a).unwrap();
        let v: Vec<f64> = (0..6).map(|_| rng.normal()).collect();
        let smw = sherman_morrison_update(&ainv, &v);
        // direct: invert A + v vᵀ
        for i in 0..6 {
            for j in 0..6 {
                a[(i, j)] += v[i] * v[j];
            }
        }
        let direct = spd_inverse(&a).unwrap();
        assert!(smw.max_abs_diff(&direct) < 1e-8);
    }

    #[test]
    #[should_panic(expected = "matmul shape mismatch")]
    fn matmul_shape_checked() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(2, 3);
        let _ = a.matmul(&b);
    }
}
