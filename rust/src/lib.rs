//! # greedy-rls
//!
//! A production-oriented reproduction of **"Linear Time Feature Selection
//! for Regularized Least-Squares"** (Pahikkala, Airola, Salakoski, 2010):
//! greedy forward feature selection for RLS / ridge regression / LS-SVM
//! with a leave-one-out (LOO) selection criterion in **O(kmn)** time —
//! linear in training examples `m`, candidate features `n`, and selected
//! features `k`.
//!
//! The crate is the Layer-3 coordinator of a three-layer architecture:
//!
//! * **Layer 1** — Pallas kernels (`python/compile/kernels/`) implement the
//!   O(mn) per-round hot spots (candidate scoring, rank-1 cache update).
//! * **Layer 2** — a JAX compute graph (`python/compile/model.py`) wires the
//!   kernels into jittable entry points, AOT-lowered once to HLO text.
//! * **Layer 3** — this crate: loads the artifacts via PJRT
//!   ([`runtime`]), owns the greedy selection loop, datasets,
//!   cross-validation, serving and benchmarking ([`coordinator`],
//!   [`select`], [`data`]). Python is never on the request path.
//!
//! A pure-Rust engine ([`select::greedy`]) implements the same algorithm
//! natively; the two engines are equivalence-tested against each other and
//! against the paper's Algorithm 1 (wrapper) and Algorithm 2 (low-rank
//! updated LS-SVM) baselines.
//!
//! The full module map — data substrate → selection sessions →
//! coordinator → runtime engines → the three serving paths — lives in
//! the repo's `ARCHITECTURE.md`.
//!
//! ## Quickstart
//!
//! The primary API is the **stepwise session**: configure with the
//! builder, `begin` a session, drive it round by round (or to
//! completion), and `finish` into a result. Early stopping on the LOO
//! plateau is one builder call (this example runs under `cargo test` —
//! every entry-point doctest in this crate does):
//!
//! ```
//! use greedy_rls::data::synthetic::two_gaussians;
//! use greedy_rls::metrics::Loss;
//! use greedy_rls::select::{
//!     greedy::GreedyRls, SelectionConfig, SessionSelector, StepOutcome,
//! };
//!
//! let ds = two_gaussians(200, 40, 6, 1.0, 42);
//! let cfg = SelectionConfig::builder()
//!     .k(12)
//!     .lambda(1.0)
//!     .loss(Loss::ZeroOne)
//!     .plateau(3, 1e-3) // stop when the LOO criterion stops improving
//!     .build();
//! let mut session = GreedyRls.begin(&ds.x, &ds.y, &cfg)?;
//! while let StepOutcome::Selected(round) = session.step()? {
//!     println!("+feature {} (LOO {})", round.feature, round.criterion);
//! }
//! let result = session.finish()?;
//! assert!(!result.selected.is_empty());
//! assert!(result.selected.len() <= cfg.k);
//! # anyhow::Ok(())
//! ```
//!
//! The blocking one-shot call is still available (and is a thin shim over
//! the session):
//!
//! ```
//! use greedy_rls::data::synthetic::two_gaussians;
//! use greedy_rls::select::{greedy::GreedyRls, SelectionConfig, Selector};
//!
//! let ds = two_gaussians(200, 40, 6, 1.0, 42);
//! let cfg = SelectionConfig::builder().k(10).build();
//! let result = GreedyRls.select(&ds.x, &ds.y, &cfg)?;
//! assert_eq!(result.selected.len(), 10);
//! # anyhow::Ok(())
//! ```
//!
//! Sessions also support warm starts
//! ([`select::SessionSelector::begin_from`]), per-round observation
//! ([`select::Observer`], fan-out via [`select::Observers`]), durable
//! checkpoints ([`select::checkpoint`]), and in-process streaming to a
//! hot-swap server ([`coordinator::stream`]) — see the module docs.

#![warn(missing_docs)]
#![cfg_attr(feature = "simd", feature(portable_simd))]

pub mod bench;
pub mod cli;
pub mod coordinator;
pub mod data;
pub mod kernel;
pub mod linalg;
pub mod metrics;
pub mod parallel;
pub mod proptest;
pub mod rls;
pub mod rng;
pub mod runtime;
pub mod select;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
