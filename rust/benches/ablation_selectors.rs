//! Ablation: selection *strategy* quality (paper §5 design space).
//!
//! The paper argues greedy forward selection is the right default and
//! sketches alternatives (backward elimination, floating search,
//! corrective/FoBa steps, n-fold criteria). This bench puts them side by
//! side on planted-sparse problems: support recovery rate, held-out
//! accuracy, and wall time — quantifying the cost/benefit of each
//! refinement over plain greedy RLS.

use greedy_rls::bench::{time_once, CellValue, Table};
use greedy_rls::coordinator::cv;
use greedy_rls::data::synthetic::planted_sparse;
use greedy_rls::metrics::{accuracy, Loss};
use greedy_rls::rng::Pcg64;
use greedy_rls::select::{
    backward::BackwardElimination, floating::FloatingForward, foba::Foba,
    greedy::GreedyRls, nfold::NFoldGreedy, random::RandomSelector,
    SelectionConfig, Selector,
};

fn main() {
    let trials = 5u64;
    let (m, n, s) = (240usize, 40usize, 6usize);
    let cfg = SelectionConfig { k: s, lambda: 1.0, loss: Loss::ZeroOne, ..Default::default() };

    let selectors: Vec<(&str, Box<dyn Selector>)> = vec![
        ("greedy-rls", Box::new(GreedyRls)),
        ("random", Box::new(RandomSelector { seed: 3 })),
        ("foba(ν=.5)", Box::new(Foba::default())),
        ("nfold(10)", Box::new(NFoldGreedy { folds: 10, seed: 3 })),
        ("backward", Box::new(BackwardElimination)),
        ("floating", Box::new(FloatingForward::default())),
    ];

    let mut table = Table::new(
        &format!(
            "Ablation — selection strategies (m={m}, n={n}, {s} informative, \
             k={s}, {trials} trials)"
        ),
        &["selector", "mean_test_acc", "informative_hit_rate", "mean_s"],
    );

    for (name, sel) in &selectors {
        let mut accs = Vec::new();
        let mut hits = 0usize;
        let mut secs = 0.0;
        for t in 0..trials {
            let ds = planted_sparse("abl", m, n, s, 1.0, 0.9, 0.05, 100 + t);
            // identify planted rows by construction: strongest |corr|
            let mut corr: Vec<(usize, f64)> = (0..n)
                .map(|i| {
                    let row = ds.x.row(i);
                    let c: f64 = row
                        .iter()
                        .zip(&ds.y)
                        .map(|(&v, &l)| v * l)
                        .sum::<f64>()
                        / m as f64;
                    (i, c.abs())
                })
                .collect();
            corr.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
            let informative: Vec<usize> =
                corr.iter().take(s).map(|&(i, _)| i).collect();

            let mut rng = Pcg64::new(t, 71);
            let (tr, te) = greedy_rls::data::folds::train_test_split(
                m, 0.25, &mut rng,
            );
            let mut train = ds.subset(&tr);
            let mut test = ds.subset(&te);
            let st = train.standardize();
            test.apply_standardization(&st);

            let mut result = None;
            secs += time_once(|| {
                result = Some(sel.select(&train.x, &train.y, &cfg));
            });
            let r = result.unwrap().expect("select");
            let p = r.predictor().predict_matrix(&test.x);
            accs.push(accuracy(&test.y, &p));
            hits += r
                .selected
                .iter()
                .filter(|i| informative.contains(i))
                .count();
        }
        let mean_acc = accs.iter().sum::<f64>() / accs.len() as f64;
        table.row(&Table::cells(&[
            CellValue::Str(name.to_string()),
            CellValue::F3(mean_acc),
            CellValue::F3(hits as f64 / (trials as usize * s) as f64),
            CellValue::F3(secs / trials as f64),
        ]));
    }
    table.print();
    let _ = table.write_csv("ablation_selectors");

    // sanity anchor: greedy must be near the top and random at the bottom
    println!(
        "\nexpected ordering: every informed strategy ≫ random; corrective \
         variants (foba/floating/backward) ≥ greedy at extra cost."
    );
    let _ = cv::holdout_accuracy; // public-API anchor used by other benches
}
