//! Hot-path microbenchmarks (EXPERIMENTS.md §Perf input).
//!
//! Per selection round, greedy RLS does exactly two O(mn) passes:
//!
//! * **score**: for each candidate, stream (x_i, c_i) twice — ≈6 flops
//!   and 2×16 bytes per (feature, example) pair;
//! * **commit**: stream every cache row once — w_i = v·c_i then the
//!   fused axpy — ≈4 flops and 24 bytes (16 read + 8 write) per pair.
//!
//! Both are memory-bandwidth-bound; this bench reports achieved GB/s and
//! GFLOP/s so the §Perf roofline discussion has hard numbers.

use greedy_rls::bench::{time, CellValue, Table};
use greedy_rls::data::synthetic::two_gaussians;
use greedy_rls::metrics::Loss;
use greedy_rls::select::greedy::GreedyState;

fn main() {
    let mut table = Table::new(
        "Microbench — per-round hot paths",
        &[
            "m",
            "n",
            "score_ms",
            "score_gbps",
            "score_gflops",
            "commit_ms",
            "commit_gbps",
        ],
    );
    for (m, n) in [(1000usize, 1000usize), (2000, 1000), (4000, 1000), (2000, 4000)] {
        let ds = two_gaussians(m, n, 50, 1.0, 3);
        let st = GreedyState::init(&ds.x, &ds.y, 1.0);

        let score = time(1, 5, || {
            std::hint::black_box(st.score_all(&ds.x, &ds.y, Loss::ZeroOne));
        });
        // bytes: X row + C row, each m f64, per candidate, streamed twice
        // (pass 1 dots, pass 2 loss) → 4 × 8 × m × n
        let score_bytes = 4.0 * 8.0 * m as f64 * n as f64;
        let score_flops = 10.0 * m as f64 * n as f64;

        // pure commit cost: one long-lived state, commit a fresh feature
        // per repetition (each commit is the same O(mn) regardless of |S|)
        let mut st2 = GreedyState::init(&ds.x, &ds.y, 1.0);
        let mut next = 0usize;
        let commit = time(1, 5, || {
            st2.commit(&ds.x, next);
            next += 1;
        });
        // commit streams every C row read+write plus X row read ≈ 3×8×mn
        let commit_bytes = 3.0 * 8.0 * m as f64 * n as f64;

        table.row(&Table::cells(&[
            CellValue::Usize(m),
            CellValue::Usize(n),
            CellValue::F3(score.median_s * 1e3),
            CellValue::F3(score_bytes / score.median_s / 1e9),
            CellValue::F3(score_flops / score.median_s / 1e9),
            CellValue::F3(commit.median_s * 1e3),
            CellValue::F3(commit_bytes / commit.median_s / 1e9),
        ]));
    }
    table.print();
    let _ = table.write_csv("microbench_hotpath");
    println!(
        "\nscore streams 32·m·n bytes per round, commit 24·m·n; achieved \
         GB/s against this box's streaming bandwidth is the roofline \
         ratio recorded in EXPERIMENTS.md §Perf."
    );
}
