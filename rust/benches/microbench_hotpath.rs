//! Hot-path microbenchmarks (EXPERIMENTS.md §Perf input).
//!
//! Per selection round, greedy RLS does exactly two O(mn) passes:
//!
//! * **score**: for each candidate, stream (x_i, c_i) twice — ≈6 flops
//!   and 2×16 bytes per (feature, example) pair;
//! * **commit**: stream every cache row once — w_i = v·c_i then the
//!   fused axpy — ≈4 flops and 24 bytes (16 read + 8 write) per pair.
//!
//! Both are memory-bandwidth-bound; this bench reports achieved GB/s and
//! GFLOP/s so the §Perf roofline discussion has hard numbers, and sweeps
//! the deterministic thread layer (`--threads 1,2,4,...`) to measure the
//! parallel speedup of both passes.
//!
//! The grid also sweeps the **kernel tier**: every row records which
//! kernel the build dispatches (`scalar`, or `simd` under
//! `--features simd`) and runs at both cache precisions (`f64` and the
//! mixed-precision `f32c`, whose cache rows are half the bytes — the
//! byte model below accounts for that, so GB/s stays comparable).
//!
//! Output: the usual table + CSV, plus a machine-readable
//! `BENCH_hotpath.json` (median ms, GB/s, GFLOP/s, speedup-vs-1-thread
//! per (m, n, kernel, precision, threads)) so the repo's perf trajectory
//! is tracked across PRs instead of living only in terminal scrollback —
//! CI compares it against the committed baseline with
//! `xtask/mirror/perf_check.py`.
//!
//! Flags (after `cargo bench --bench microbench_hotpath --`):
//! `--threads L` comma-separated thread counts (default `1,2,4` plus the
//! machine's available parallelism); `--smoke` shrinks the grid to one
//! tiny (m, n) for CI.

use greedy_rls::bench::{time, CellValue, Table};
use greedy_rls::data::synthetic::two_gaussians;
use greedy_rls::kernel::{KernelKind, Precision};
use greedy_rls::metrics::Loss;
use greedy_rls::parallel;
use greedy_rls::select::greedy::GreedyState;

struct Record {
    m: usize,
    n: usize,
    kernel: &'static str,
    precision: &'static str,
    threads: usize,
    score_ms: f64,
    score_gbps: f64,
    score_gflops: f64,
    commit_ms: f64,
    commit_gbps: f64,
    score_speedup_vs_1t: f64,
}

fn parse_args() -> (Vec<usize>, bool) {
    let mut threads: Vec<usize> = vec![1, 2, 4, parallel::available()];
    threads.sort_unstable();
    threads.dedup();
    let mut smoke = false;
    let mut it = std::env::args().skip(1);
    while let Some(arg) = it.next() {
        match arg.as_str() {
            "--threads" => {
                let list = it.next().expect("--threads needs a value");
                threads = list
                    .split(',')
                    .map(|t| t.trim().parse().expect("--threads T1,T2,..."))
                    .collect();
                assert!(
                    threads.iter().all(|&t| t >= 1),
                    "thread counts must be >= 1"
                );
                // ascending order guarantees the 1-thread baseline (when
                // present) is measured before its speedup consumers;
                // without 1 in the list the speedup column is null
                threads.sort_unstable();
                threads.dedup();
            }
            "--smoke" => smoke = true,
            _ => {} // ignore cargo-bench harness flags (--bench, ...)
        }
    }
    (threads, smoke)
}

fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.6}")
    } else {
        "null".to_string()
    }
}

fn write_json(records: &[Record]) -> std::io::Result<()> {
    let mut out = String::from("{\n");
    out.push_str(&format!(
        "  \"available_parallelism\": {},\n",
        parallel::available()
    ));
    out.push_str("  \"results\": [\n");
    for (i, r) in records.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"m\": {}, \"n\": {}, \"kernel\": \"{}\", \
             \"precision\": \"{}\", \"threads\": {}, \
             \"score_ms\": {}, \"score_gbps\": {}, \"score_gflops\": {}, \
             \"commit_ms\": {}, \"commit_gbps\": {}, \
             \"score_speedup_vs_1t\": {}}}{}\n",
            r.m,
            r.n,
            r.kernel,
            r.precision,
            r.threads,
            json_num(r.score_ms),
            json_num(r.score_gbps),
            json_num(r.score_gflops),
            json_num(r.commit_ms),
            json_num(r.commit_gbps),
            json_num(r.score_speedup_vs_1t),
            if i + 1 < records.len() { "," } else { "" },
        ));
    }
    out.push_str("  ]\n}\n");
    std::fs::write("BENCH_hotpath.json", out)
}

/// Bytes streamed per (feature, example) pair by the score pass: X row
/// twice in f64 plus the cache row twice at its storage width.
fn score_bytes_per_pair(precision: Precision) -> f64 {
    match precision {
        Precision::F64 => 4.0 * 8.0,
        Precision::F32c => 2.0 * 8.0 + 2.0 * 4.0,
    }
}

/// Bytes per pair for the commit pass: cache row read + write at its
/// storage width plus the X row read in f64.
fn commit_bytes_per_pair(precision: Precision) -> f64 {
    match precision {
        Precision::F64 => 3.0 * 8.0,
        Precision::F32c => 8.0 + 2.0 * 4.0,
    }
}

fn main() {
    let (threads, smoke) = parse_args();
    let sizes: Vec<(usize, usize)> = if smoke {
        vec![(200, 64)]
    } else {
        vec![(1000, 1000), (2000, 1000), (4000, 1000), (2000, 4000)]
    };
    let kernel = KernelKind::active().as_str();
    let precisions = [Precision::F64, Precision::F32c];

    let mut table = Table::new(
        "Microbench — per-round hot paths",
        &[
            "m",
            "n",
            "kernel",
            "precision",
            "threads",
            "score_ms",
            "score_gbps",
            "score_gflops",
            "commit_ms",
            "commit_gbps",
            "score_speedup",
        ],
    );
    let mut records: Vec<Record> = Vec::new();
    for &(m, n) in &sizes {
        let ds = two_gaussians(m, n, 50.min(n), 1.0, 3);
        for &prec in &precisions {
            let mut score_1t_ms = f64::NAN;
            for &t in &threads {
                let mut st =
                    GreedyState::init(&ds.x, &ds.y, 1.0).with_threads(t);
                if prec == Precision::F32c {
                    st = st.with_precision(prec);
                }
                let score = time(1, 5, || {
                    std::hint::black_box(
                        st.score_all(&ds.x, &ds.y, Loss::ZeroOne),
                    );
                });
                let score_bytes =
                    score_bytes_per_pair(prec) * m as f64 * n as f64;
                let score_flops = 10.0 * m as f64 * n as f64;

                // pure commit cost: one long-lived state, commit a fresh
                // feature per repetition (each commit is the same O(mn)
                // regardless of |S|)
                let mut st2 =
                    GreedyState::init(&ds.x, &ds.y, 1.0).with_threads(t);
                if prec == Precision::F32c {
                    st2 = st2.with_precision(prec);
                }
                let mut next = 0usize;
                let commit = time(1, 5, || {
                    st2.commit(&ds.x, next);
                    next += 1;
                });
                let commit_bytes =
                    commit_bytes_per_pair(prec) * m as f64 * n as f64;

                let score_ms = score.median_s * 1e3;
                if t == 1 {
                    score_1t_ms = score_ms;
                }
                let speedup = score_1t_ms / score_ms;
                records.push(Record {
                    m,
                    n,
                    kernel,
                    precision: prec.as_str(),
                    threads: t,
                    score_ms,
                    score_gbps: score_bytes / score.median_s / 1e9,
                    score_gflops: score_flops / score.median_s / 1e9,
                    commit_ms: commit.median_s * 1e3,
                    commit_gbps: commit_bytes / commit.median_s / 1e9,
                    score_speedup_vs_1t: speedup,
                });
                let r = records.last().unwrap();
                table.row(&Table::cells(&[
                    CellValue::Usize(m),
                    CellValue::Usize(n),
                    CellValue::Str(r.kernel.to_string()),
                    CellValue::Str(r.precision.to_string()),
                    CellValue::Usize(t),
                    CellValue::F3(r.score_ms),
                    CellValue::F3(r.score_gbps),
                    CellValue::F3(r.score_gflops),
                    CellValue::F3(r.commit_ms),
                    CellValue::F3(r.commit_gbps),
                    CellValue::F3(r.score_speedup_vs_1t),
                ]));
            }
        }
    }
    table.print();
    let _ = table.write_csv("microbench_hotpath");
    match write_json(&records) {
        Ok(()) => println!("\nmachine-readable: BENCH_hotpath.json"),
        Err(e) => eprintln!("\nfailed to write BENCH_hotpath.json: {e}"),
    }
    println!(
        "score streams 32·m·n bytes per round at f64 (24 at f32c), commit \
         24·m·n (16 at f32c); achieved GB/s against this box's streaming \
         bandwidth is the roofline ratio recorded in EXPERIMENTS.md §Perf. \
         Speedups are vs the 1-thread run of the same (m, n, kernel, \
         precision); results are bit-identical at every thread count \
         within one (kernel, precision) pair."
    );
}
