//! Figures 1 & 2: running time of greedy RLS (Algorithm 3) vs the
//! low-rank updated LS-SVM (Algorithm 2) as the training-set size m grows.
//!
//! Paper workload: two-Gaussian data, n = 1000 features, k = 50 selected,
//! m = 500..5000. The baseline is O(km²n) — on this single-vCPU testbed
//! the paper's exact grid would run for hours (as it did for the authors:
//! their Fig. 1 y-axis tops out near 10⁴ CPU-seconds), so the default
//! grid is scaled down; set `GREEDY_RLS_BENCH_FULL=1` for the paper's.
//!
//! Expected shape (not absolute seconds): the baseline's log-log slope vs
//! m ≈ 2 (quadratic), greedy's ≈ 1 (linear), with greedy faster
//! everywhere and the gap widening as m grows.

use greedy_rls::bench::{time_once, CellValue, Table, TimingObserver};
use greedy_rls::data::synthetic::two_gaussians;
use greedy_rls::metrics::Loss;
use greedy_rls::select::{
    drive, greedy::GreedyRls, lowrank::LowRankLsSvm, NoopObserver,
    SelectionConfig, Selector, SessionSelector,
};

fn log_log_slope(xs: &[f64], ys: &[f64]) -> f64 {
    // least-squares slope of log y on log x
    let lx: Vec<f64> = xs.iter().map(|v| v.ln()).collect();
    let ly: Vec<f64> = ys.iter().map(|v| v.ln()).collect();
    let n = lx.len() as f64;
    let mx = lx.iter().sum::<f64>() / n;
    let my = ly.iter().sum::<f64>() / n;
    let cov: f64 = lx.iter().zip(&ly).map(|(a, b)| (a - mx) * (b - my)).sum();
    let var: f64 = lx.iter().map(|a| (a - mx) * (a - mx)).sum();
    cov / var
}

fn main() {
    let full = std::env::var("GREEDY_RLS_BENCH_FULL").is_ok();
    let (n, k, ms): (usize, usize, Vec<usize>) = if full {
        (
            1000,
            50,
            vec![500, 1000, 1500, 2000, 2500, 3000, 3500, 4000, 4500, 5000],
        )
    } else {
        (200, 10, vec![300, 600, 900, 1200])
    };

    let max_threads = greedy_rls::parallel::available();
    let mut table = Table::new(
        &format!("Fig 1/2 — runtime vs m (n={n}, k={k}, two-Gaussian)"),
        &[
            "m",
            "greedy_s",
            "greedy_par_s",
            "par_threads",
            "par_speedup",
            "lowrank_s",
            "speedup",
            "log10_greedy",
            "log10_lowrank",
        ],
    );
    let cfg = SelectionConfig {
        k,
        lambda: 1.0,
        loss: Loss::ZeroOne,
        threads: 1,
        ..Default::default()
    };
    let cfg_par = SelectionConfig { threads: max_threads, ..cfg };
    let (mut tg, mut tl) = (Vec::new(), Vec::new());
    let mut last_obs: Option<TimingObserver> = None;
    for &m in &ms {
        let ds = two_gaussians(m, n, 50.min(n), 1.0, 42);
        // greedy runs as a session: one run yields both the total and the
        // per-round timing (no re-running per k)
        let mut obs = TimingObserver::default();
        let t_g = time_once(|| {
            let mut session = GreedyRls.begin(&ds.x, &ds.y, &cfg).unwrap();
            drive(session.as_mut(), &mut obs).unwrap();
            session.finish().unwrap();
        });
        // the same run on the deterministic thread layer (bit-identical
        // selections — only the wall-clock differs)
        let t_gp = time_once(|| {
            let mut session = GreedyRls.begin(&ds.x, &ds.y, &cfg_par).unwrap();
            drive(session.as_mut(), &mut NoopObserver).unwrap();
            session.finish().unwrap();
        });
        let t_l = time_once(|| {
            LowRankLsSvm.select(&ds.x, &ds.y, &cfg).unwrap();
        });
        tg.push(t_g);
        tl.push(t_l);
        last_obs = Some(obs);
        table.row(&Table::cells(&[
            CellValue::Usize(m),
            CellValue::F3(t_g),
            CellValue::F3(t_gp),
            CellValue::Usize(max_threads),
            CellValue::F3(t_g / t_gp),
            CellValue::F3(t_l),
            CellValue::F3(t_l / t_g),
            CellValue::F3(t_g.log10()),
            CellValue::F3(t_l.log10()),
        ]));
    }
    table.print();
    let _ = table.write_csv("fig1_2_scaling_vs_lowrank");

    if let Some(obs) = &last_obs {
        let first = obs.per_round_s.first().copied().unwrap_or(0.0);
        let last = obs.per_round_s.last().copied().unwrap_or(0.0);
        println!(
            "\nper-round greedy timing at m={} (from one session, {} rounds): \
             first {:.4}s, last {:.4}s — flat ⇒ every round is O(mn)",
            ms.last().unwrap(),
            obs.per_round_s.len(),
            first,
            last
        );
    }

    let ms_f: Vec<f64> = ms.iter().map(|&m| m as f64).collect();
    let slope_g = log_log_slope(&ms_f, &tg);
    let slope_l = log_log_slope(&ms_f, &tl);
    println!("\nlog-log slope vs m: greedy {slope_g:.2} (paper: ≈1, linear)");
    println!("log-log slope vs m: lowrank {slope_l:.2} (paper: ≈2, quadratic)");
    println!(
        "shape check: lowrank slope − greedy slope = {:.2} (expect ≈ +1)",
        slope_l - slope_g
    );
}
