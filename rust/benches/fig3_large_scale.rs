//! Figure 3: greedy RLS running time alone, scaling m into the tens of
//! thousands (the regime where the Algorithm-2 baseline is infeasible —
//! the paper reports 50 features from 1000 at m = 50 000 in "a bit less
//! than twelve minutes" on a 2009 desktop).
//!
//! Default grid caps at m = 20 000 on this single-vCPU box; set
//! `GREEDY_RLS_BENCH_FULL=1` for the paper's m = 50 000 endpoint.
//! Shape check: seconds per unit of k·m·n must stay constant (linearity).

use greedy_rls::bench::{time_once, CellValue, Table, TimingObserver};
use greedy_rls::data::synthetic::two_gaussians;
use greedy_rls::metrics::Loss;
use greedy_rls::select::{
    drive, greedy::GreedyRls, SelectionConfig, SessionSelector,
};

fn main() {
    let full = std::env::var("GREEDY_RLS_BENCH_FULL").is_ok();
    let (n, k) = (1000usize, 50usize);
    let ms: Vec<usize> = if full {
        vec![1000, 5000, 10000, 20000, 30000, 40000, 50000]
    } else {
        vec![1000, 2000, 5000, 10000, 20000]
    };
    let cfg = SelectionConfig {
        k,
        lambda: 1.0,
        loss: Loss::ZeroOne,
        ..Default::default()
    };

    let mut thread_series = vec![1usize, greedy_rls::parallel::available()];
    thread_series.dedup();

    let mut table = Table::new(
        &format!("Fig 3 — greedy RLS runtime, n={n}, k={k}"),
        &["m", "threads", "seconds", "ns_per_kmn", "gflops", "round_spread"],
    );
    let mut units = Vec::new(); // 1-thread series (linearity claim)
    let mut speedup_at_max_m = f64::NAN;
    for &m in &ms {
        let ds = two_gaussians(m, n, 50, 1.0, 43);
        let mut secs_1t = f64::NAN;
        for &t in &thread_series {
            let cfg_t = SelectionConfig { threads: t, ..cfg };
            // one session run: total seconds AND per-round flatness check
            let mut obs = TimingObserver::default();
            let secs = time_once(|| {
                let mut session =
                    GreedyRls.begin(&ds.x, &ds.y, &cfg_t).unwrap();
                drive(session.as_mut(), &mut obs).unwrap();
                session.finish().unwrap();
            });
            // max/min per-round time: ≈1 ⇒ every round is the same O(mn)
            let round_spread = {
                let max =
                    obs.per_round_s.iter().cloned().fold(f64::MIN, f64::max);
                let min =
                    obs.per_round_s.iter().cloned().fold(f64::MAX, f64::min);
                if min > 0.0 { max / min } else { f64::NAN }
            };
            // per-round work ≈ score (6 mul+add × mn) + commit (4 × mn)
            let flops = k as f64 * m as f64 * n as f64 * 10.0;
            let unit = secs * 1e9 / (k as f64 * m as f64 * n as f64);
            if t == 1 {
                secs_1t = secs;
                units.push(unit);
            } else if m == *ms.last().unwrap() {
                speedup_at_max_m = secs_1t / secs;
            }
            table.row(&Table::cells(&[
                CellValue::Usize(m),
                CellValue::Usize(t),
                CellValue::F3(secs),
                CellValue::F3(unit),
                CellValue::F3(flops / secs / 1e9),
                CellValue::F3(round_spread),
            ]));
        }
    }
    table.print();
    let _ = table.write_csv("fig3_large_scale");

    let spread = units.iter().cloned().fold(f64::MIN, f64::max)
        / units.iter().cloned().fold(f64::MAX, f64::min);
    println!(
        "\nns per k·m·n spread across the 1-thread grid: ×{spread:.2} \
         (≈1 ⇒ the paper's O(kmn) linear-scaling claim holds)"
    );
    if let Some(&t) = thread_series.last() {
        if t > 1 {
            println!(
                "parallel speedup at m={} with {t} threads: ×{:.2} \
                 (bit-identical selections — wall-clock only)",
                ms.last().unwrap(),
                speedup_at_max_m
            );
        }
    }
}
