//! Figures 10–15: test vs LOO accuracy per number of selected features
//! (paper §4.3 — how much does the LOO selection criterion overfit?).
//!
//! Expected shape: on large-m datasets (adult, australian, ijcnn1,
//! mnist5) the two curves nearly coincide; on colon-cancer (m=62,
//! n=2000) and to a lesser degree german.numer the LOO estimate is
//! visibly over-optimistic — "reliable feature selection can be
//! problematic on small high-dimensional data sets".

use greedy_rls::bench::{CellValue, Table};
use greedy_rls::coordinator::cv;
use greedy_rls::data::registry;
use greedy_rls::rng::Pcg64;

fn main() {
    let full = std::env::var("GREEDY_RLS_BENCH_FULL").is_ok();
    let figure_of = |name: &str| match name {
        "adult" => 10,
        "australian" => 11,
        "colon-cancer" => 12,
        "german.numer" => 13,
        "ijcnn1" => 14,
        "mnist5" => 15,
        _ => 0,
    };

    let mut gaps: Vec<(String, usize, usize, f64)> = Vec::new();
    for spec in registry::SPECS {
        let mut ds = registry::load(spec.name, false, 42).expect("load");
        let cap = if full { usize::MAX } else { 1500 };
        if ds.n_examples() > cap {
            let mut rng = Pcg64::seeded(11);
            let idx = rng.choose_distinct(ds.n_examples(), cap);
            ds = ds.subset(&idx);
        }
        let folds = if ds.n_examples() < 100 { 5 } else if full { 10 } else { 5 };
        let kmax = ds.n_features().min(if full { 40 } else { 16 });
        let curves = cv::run_cv(&ds, folds, kmax, 43).expect("cv");

        let mut table = Table::new(
            &format!(
                "Fig {} — {} (m={}, n={}), test vs LOO accuracy",
                figure_of(spec.name),
                spec.name,
                ds.n_examples(),
                ds.n_features()
            ),
            &["k", "test_acc", "loo_acc", "gap"],
        );
        let mut max_gap = 0.0_f64;
        for (i, k) in curves.ks.iter().enumerate() {
            let gap = curves.greedy_loo[i] - curves.greedy_test[i];
            max_gap = max_gap.max(gap);
            table.row(&Table::cells(&[
                CellValue::Usize(*k),
                CellValue::F3(curves.greedy_test[i]),
                CellValue::F3(curves.greedy_loo[i]),
                CellValue::F3(gap),
            ]));
        }
        table.print();
        let _ = table.write_csv(&format!(
            "fig{}_{}_overfit",
            figure_of(spec.name),
            spec.name.replace(['.', '-'], "_")
        ));
        gaps.push((
            spec.name.to_string(),
            ds.n_examples(),
            ds.n_features(),
            max_gap,
        ));
    }

    println!("\n== overfitting summary (max LOO − test gap) ==");
    for (name, m, n, gap) in &gaps {
        println!(
            "{name:<14} m={m:<6} n={n:<5} max gap {gap:+.3} {}",
            if *gap > 0.08 { "<-- LOO over-optimistic" } else { "" }
        );
    }
    let colon = gaps.iter().find(|g| g.0 == "colon-cancer").unwrap();
    let big: Vec<&(String, usize, usize, f64)> =
        gaps.iter().filter(|g| g.1 >= 600).collect();
    let avg_big: f64 =
        big.iter().map(|g| g.3).sum::<f64>() / big.len() as f64;
    println!(
        "\nshape check: colon-cancer gap {:+.3} vs large-m average {:+.3} \
         (paper: small-m/high-n overfits, large-m tracks)",
        colon.3, avg_big
    );
}
