//! Table 1: benchmark dataset characteristics (+ provenance and a
//! selection smoke metric per dataset).
//!
//! The paper's table lists #instances and #features for the six LIBSVM
//! datasets. This environment is offline, so each dataset resolves to a
//! synthetic stand-in with the paper's exact shape (or a documented
//! scaled-down m — printed in the `loaded_m` column; `data/real/*.libsvm`
//! files are used instead when present). See DESIGN.md §6.

use greedy_rls::bench::{CellValue, Table};
use greedy_rls::coordinator::cv;
use greedy_rls::data::registry;
use greedy_rls::metrics::Loss;
use greedy_rls::select::SelectionConfig;

fn main() {
    let full = std::env::var("GREEDY_RLS_BENCH_FULL").is_ok();
    let mut table = Table::new(
        "Table 1 — data sets",
        &[
            "dataset",
            "paper_m",
            "paper_n",
            "loaded_m",
            "loaded_n",
            "pos_frac",
            "holdout_acc_k10",
        ],
    );
    for spec in registry::SPECS {
        let ds = registry::load(spec.name, full, 42).expect("load");
        let k = 10.min(ds.n_features());
        // λ by full-feature LOO grid search (the paper's §4.2 protocol)
        let mut scaled = ds.clone();
        scaled.standardize();
        let (lambda, _) = greedy_rls::coordinator::grid::search(
            &scaled.x,
            &scaled.y,
            &greedy_rls::coordinator::grid::default_grid(),
            Loss::ZeroOne,
        );
        let cfg = SelectionConfig { k, lambda, loss: Loss::ZeroOne, ..Default::default() };
        let (acc, _) = cv::holdout_accuracy(&ds, 0.25, &cfg, 7).expect("cv");
        table.row(&Table::cells(&[
            CellValue::Str(spec.name.to_string()),
            CellValue::Usize(spec.paper_m),
            CellValue::Usize(spec.paper_n),
            CellValue::Usize(ds.n_examples()),
            CellValue::Usize(ds.n_features()),
            CellValue::F3(ds.positive_fraction()),
            CellValue::F3(acc),
        ]));
    }
    table.print();
    let _ = table.write_csv("table1_datasets");
    println!(
        "\npaper_m/paper_n match Table 1 verbatim; loaded_m is the \
         documented scaled default (GREEDY_RLS_BENCH_FULL=1 for full m)."
    );
}
