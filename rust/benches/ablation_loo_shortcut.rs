//! Ablation: the paper's complexity ladder, measured.
//!
//! Four implementations of the *same* selection (§3 of the paper):
//!
//! 1. wrapper + brute-force LOO      O(min{k³m²n, k²m³n})   (Algorithm 1)
//! 2. wrapper + eq. 7/8 LOO shortcut O(min{k³mn, k²m²n})    (§3.1 note)
//! 3. low-rank updated LS-SVM        O(km²n)                (Algorithm 2)
//! 4. greedy RLS                     O(kmn)                 (Algorithm 3)
//!
//! All four must pick identical features (asserted); the runtimes should
//! reproduce the ladder, including the paper's observation that for large
//! m and small k the shortcut wrapper can beat the low-rank method.

use greedy_rls::bench::{time_once, CellValue, Table};
use greedy_rls::data::synthetic::two_gaussians;
use greedy_rls::metrics::Loss;
use greedy_rls::select::{
    greedy::GreedyRls, lowrank::LowRankLsSvm, wrapper::Wrapper,
    SelectionConfig, Selector,
};

fn main() {
    let full = std::env::var("GREEDY_RLS_BENCH_FULL").is_ok();
    let grid: Vec<(usize, usize, usize)> = if full {
        vec![(40, 60, 5), (40, 120, 5), (40, 240, 5), (80, 240, 5)]
    } else {
        vec![(30, 50, 4), (30, 100, 4), (30, 200, 4)]
    };

    let mut table = Table::new(
        "Ablation — LOO evaluation strategy (same selections, 4 algorithms)",
        &["n", "m", "k", "wrap_brute_s", "wrap_short_s", "lowrank_s", "greedy_s"],
    );
    for &(n, m, k) in &grid {
        let ds = two_gaussians(m, n, (n / 4).max(1), 1.0, 13);
        let cfg = SelectionConfig { k, lambda: 1.0, loss: Loss::Squared, ..Default::default() };
        let mut sel: Vec<Vec<usize>> = Vec::new();
        let mut t = Vec::new();
        let selectors: Vec<Box<dyn Selector>> = vec![
            Box::new(Wrapper::brute_force()),
            Box::new(Wrapper::shortcut()),
            Box::new(LowRankLsSvm),
            Box::new(GreedyRls),
        ];
        for s in &selectors {
            let mut result = None;
            let secs = time_once(|| {
                result = Some(s.select(&ds.x, &ds.y, &cfg).unwrap());
            });
            sel.push(result.unwrap().selected);
            t.push(secs);
        }
        for w in sel.windows(2) {
            assert_eq!(w[0], w[1], "algorithms disagreed!");
        }
        table.row(&Table::cells(&[
            CellValue::Usize(n),
            CellValue::Usize(m),
            CellValue::Usize(k),
            CellValue::F6(t[0]),
            CellValue::F6(t[1]),
            CellValue::F6(t[2]),
            CellValue::F6(t[3]),
        ]));
    }
    table.print();
    let _ = table.write_csv("ablation_loo_shortcut");
    println!(
        "\nladder check: brute ≥ shortcut ≥ lowrank ≥ greedy on every row \
         (crossover caveats per the paper's §3.2 discussion)."
    );
}
