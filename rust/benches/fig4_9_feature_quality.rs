//! Figures 4–9: test accuracy vs number of selected features, greedy RLS
//! vs the random-selection baseline, stratified CV on each benchmark
//! dataset (paper §4.2).
//!
//! Expected shape per dataset: greedy dominates random at (almost) every
//! k, rises fast over the first informative features, and plateaus near
//! the full-feature accuracy with a small subset.
//!
//! Defaults are sized for a single-vCPU bench run (reduced folds/k and
//! subsampled large datasets); `GREEDY_RLS_BENCH_FULL=1` runs the paper's
//! 10 folds to larger k.

use greedy_rls::bench::{CellValue, Table};
use greedy_rls::coordinator::cv;
use greedy_rls::data::registry;
use greedy_rls::rng::Pcg64;

fn main() {
    let full = std::env::var("GREEDY_RLS_BENCH_FULL").is_ok();
    let figure_of = |name: &str| match name {
        "adult" => 4,
        "australian" => 5,
        "colon-cancer" => 6,
        "german.numer" => 7,
        "ijcnn1" => 8,
        "mnist5" => 9,
        _ => 0,
    };

    for spec in registry::SPECS {
        let mut ds = registry::load(spec.name, false, 42).expect("load");
        // subsample very large stand-ins for bench turnaround
        let cap = if full { usize::MAX } else { 1500 };
        if ds.n_examples() > cap {
            let mut rng = Pcg64::seeded(9);
            let idx = rng.choose_distinct(ds.n_examples(), cap);
            ds = ds.subset(&idx);
        }
        let folds = if ds.n_examples() < 100 {
            5
        } else if full {
            10
        } else {
            5
        };
        let kmax = ds.n_features().min(if full { 40 } else { 16 });
        let curves = cv::run_cv(&ds, folds, kmax, 42).expect("cv");

        let mut table = Table::new(
            &format!(
                "Fig {} — {} (m={}, n={}), greedy vs random, {}-fold CV",
                figure_of(spec.name),
                spec.name,
                ds.n_examples(),
                ds.n_features(),
                folds
            ),
            &["k", "greedy_test", "random_test", "greedy_std"],
        );
        for (i, k) in curves.ks.iter().enumerate() {
            table.row(&Table::cells(&[
                CellValue::Usize(*k),
                CellValue::F3(curves.greedy_test[i]),
                CellValue::F3(curves.random_test[i]),
                CellValue::F3(curves.greedy_test_std[i]),
            ]));
        }
        table.print();
        let _ = table.write_csv(&format!(
            "fig{}_{}_quality",
            figure_of(spec.name),
            spec.name.replace(['.', '-'], "_")
        ));
        let wins = curves
            .greedy_test
            .iter()
            .zip(&curves.random_test)
            .filter(|(g, r)| g >= r)
            .count();
        println!(
            "shape check: greedy ≥ random at {wins}/{} of the k grid \
             (paper: clear dominance)\n",
            curves.ks.len()
        );
    }
}
