//! Ablation: native Rust engines vs the PJRT artifact engines.
//!
//! Both execute identical selection math (equivalence-tested); this bench
//! quantifies the cost of the PJRT path — literal marshalling,
//! host↔device copies of the padded bucket, and XLA kernel dispatch per
//! round — against the cache-tight native loops, at each artifact bucket
//! and for every selector with an artifact engine (greedy, backward,
//! nfold, foba, floating).
//!
//! This is an ablation of the three-layer architecture itself: it answers
//! "what does routing each selector's hot loop through the AOT artifacts
//! cost on CPU, per selection round?".

use greedy_rls::bench::{time, CellValue, Table};
use greedy_rls::data::synthetic::two_gaussians;
use greedy_rls::metrics::Loss;
use greedy_rls::runtime::{
    engine::{PjrtBackward, PjrtFloating, PjrtFoba, PjrtGreedy, PjrtNFold},
    Runtime,
};
use greedy_rls::select::{
    backward::BackwardElimination, floating::FloatingForward, foba::Foba,
    greedy::GreedyRls, nfold::NFoldGreedy, SelectionConfig, Selector,
};

fn main() {
    let Ok(rt) = Runtime::open("artifacts") else {
        println!("artifacts not built — run `make artifacts` first");
        return;
    };
    let k = 8usize;
    let mut table = Table::new(
        &format!("Ablation — native vs PJRT engines (k={k})"),
        &[
            "selector",
            "bucket_m",
            "bucket_n",
            "native_s",
            "pjrt_s",
            "pjrt_per_round_ms",
            "overhead_x",
        ],
    );
    for (mb, nb) in rt.selection_buckets() {
        // fill ~80% of the bucket so padding is realistic
        let m = (mb * 4) / 5;
        let n = (nb * 4) / 5;
        if k >= n {
            continue;
        }
        let ds = two_gaussians(m, n, (n / 5).max(1), 1.0, 7);
        let cfg = SelectionConfig { k, lambda: 1.0, loss: Loss::ZeroOne, ..Default::default() };
        let nfold = NFoldGreedy::default();
        // (selector, native one-shot, pjrt one-shot, rounds for per-round
        // normalization — backward eliminates n − k features per run)
        let cases: Vec<(&str, Box<dyn Fn() + '_>, Box<dyn Fn() + '_>, usize)> = vec![
            (
                "greedy",
                Box::new(|| {
                    GreedyRls.select(&ds.x, &ds.y, &cfg).unwrap();
                }),
                Box::new(|| {
                    PjrtGreedy::new(&rt).select(&ds.x, &ds.y, &cfg).unwrap();
                }),
                k,
            ),
            (
                "backward",
                Box::new(|| {
                    BackwardElimination.select(&ds.x, &ds.y, &cfg).unwrap();
                }),
                Box::new(|| {
                    PjrtBackward::new(&rt)
                        .select(&ds.x, &ds.y, &cfg)
                        .unwrap();
                }),
                n - k,
            ),
            (
                "nfold",
                Box::new(|| {
                    nfold.select(&ds.x, &ds.y, &cfg).unwrap();
                }),
                Box::new(|| {
                    PjrtNFold::with_params(&rt, nfold)
                        .select(&ds.x, &ds.y, &cfg)
                        .unwrap();
                }),
                k,
            ),
            (
                "foba",
                Box::new(|| {
                    Foba::default().select(&ds.x, &ds.y, &cfg).unwrap();
                }),
                Box::new(|| {
                    PjrtFoba::new(&rt).select(&ds.x, &ds.y, &cfg).unwrap();
                }),
                k,
            ),
            (
                "floating",
                Box::new(|| {
                    FloatingForward::default()
                        .select(&ds.x, &ds.y, &cfg)
                        .unwrap();
                }),
                Box::new(|| {
                    PjrtFloating::new(&rt)
                        .select(&ds.x, &ds.y, &cfg)
                        .unwrap();
                }),
                k,
            ),
        ];
        for (name, native_fn, pjrt_fn, rounds) in &cases {
            // the quadratic-init selectors get prohibitively slow at the
            // big buckets — keep the table fillable in one sitting
            if (mb * nb) > 512 * 1024 && *name != "greedy" {
                continue;
            }
            let native = time(1, 3, native_fn);
            let pjrt = time(1, 3, pjrt_fn);
            table.row(&Table::cells(&[
                CellValue::Str(name.to_string()),
                CellValue::Usize(mb),
                CellValue::Usize(nb),
                CellValue::F6(native.median_s),
                CellValue::F6(pjrt.median_s),
                CellValue::F3(pjrt.median_s / *rounds as f64 * 1e3),
                CellValue::F3(pjrt.median_s / native.median_s),
            ]));
        }
    }
    table.print();
    let _ = table.write_csv("ablation_engines");
    println!(
        "\nnative wins on CPU (no marshalling, f64 cache-tight loops); the \
         PJRT path is the TPU-ready architecture demonstrating L1/L2 \
         kernels on the request path with zero Python — now for every \
         scan-shaped selector, not just greedy."
    );
}
