//! Ablation: native Rust engine vs the PJRT artifact engine.
//!
//! Both execute the identical Algorithm-3 math (equivalence-tested); this
//! bench quantifies the cost of the PJRT path — literal marshalling,
//! host↔device copies of the padded bucket, and XLA kernel dispatch per
//! round — against the cache-tight native loop, at each artifact bucket.
//!
//! This is an ablation of the three-layer architecture itself: it answers
//! "what does routing the hot loop through the AOT artifacts cost on CPU,
//! per selection round?".

use greedy_rls::bench::{time, CellValue, Table};
use greedy_rls::data::synthetic::two_gaussians;
use greedy_rls::metrics::Loss;
use greedy_rls::runtime::{engine::PjrtGreedy, Runtime};
use greedy_rls::select::{greedy::GreedyRls, SelectionConfig, Selector};

fn main() {
    let Ok(rt) = Runtime::open("artifacts") else {
        println!("artifacts not built — run `make artifacts` first");
        return;
    };
    let k = 8usize;
    let mut table = Table::new(
        &format!("Ablation — native vs PJRT engine (k={k})"),
        &[
            "bucket_m",
            "bucket_n",
            "native_s",
            "pjrt_s",
            "pjrt_per_round_ms",
            "overhead_x",
        ],
    );
    for (mb, nb) in rt.selection_buckets() {
        // fill ~80% of the bucket so padding is realistic
        let m = (mb * 4) / 5;
        let n = (nb * 4) / 5;
        if k >= n {
            continue;
        }
        let ds = two_gaussians(m, n, (n / 5).max(1), 1.0, 7);
        let cfg = SelectionConfig { k, lambda: 1.0, loss: Loss::ZeroOne, ..Default::default() };
        let native = time(1, 3, || {
            GreedyRls.select(&ds.x, &ds.y, &cfg).unwrap();
        });
        let pjrt = time(1, 3, || {
            PjrtGreedy::new(&rt).select(&ds.x, &ds.y, &cfg).unwrap();
        });
        table.row(&Table::cells(&[
            CellValue::Usize(mb),
            CellValue::Usize(nb),
            CellValue::F6(native.median_s),
            CellValue::F6(pjrt.median_s),
            CellValue::F3(pjrt.median_s / k as f64 * 1e3),
            CellValue::F3(pjrt.median_s / native.median_s),
        ]));
    }
    table.print();
    let _ = table.write_csv("ablation_engines");
    println!(
        "\nnative wins on CPU (no marshalling, f64 cache-tight loop); the \
         PJRT path is the TPU-ready architecture demonstrating L1/L2 \
         kernels on the request path with zero Python."
    );
}
