//! Serving-fabric load bench: drive an in-process `serve --listen`
//! front with the fabric load generator (`listen::run_load`) and record
//! throughput and latency as client connections scale, plus one
//! deliberately saturated leg (single slowed worker, depth-1 queue)
//! that measures admission-control shedding instead of letting latency
//! queue unboundedly.
//!
//! The served model is a real greedy-RLS selection over a synthetic
//! dataset, so answered queries exercise the same sparse predictor the
//! fleet gauntlet ships between processes.
//!
//! Output: the usual table + CSV, plus machine-readable
//! `BENCH_serve.json` (sent/answered/shed, p50/p99 ms, achieved QPS per
//! leg) so serving-path regressions show up across PRs.
//!
//! Flags (after `cargo bench --bench serve_load --`): `--smoke` shrinks
//! the dataset and query counts for CI.

use std::sync::Arc;
use std::time::Duration;

use greedy_rls::bench::{CellValue, Table};
use greedy_rls::coordinator::fabric::listen::{
    run_load, ListenOptions, ListenServer, LoadOptions,
};
use greedy_rls::coordinator::fabric::net::Addr;
use greedy_rls::coordinator::fabric::FabricOptions;
use greedy_rls::coordinator::serve::HotSwapServer;
use greedy_rls::data::synthetic::two_gaussians;
use greedy_rls::select::greedy::GreedyRls;
use greedy_rls::select::{SelectionConfig, SessionSelector};

struct Leg {
    label: &'static str,
    connections: usize,
    workers: usize,
    queue_depth: usize,
    worker_delay: Duration,
}

struct Record {
    label: &'static str,
    connections: usize,
    workers: usize,
    queue_depth: usize,
    sent: u64,
    answered: u64,
    shed: u64,
    p50_ms: f64,
    p99_ms: f64,
    qps: f64,
}

fn parse_args() -> bool {
    std::env::args().skip(1).any(|a| a == "--smoke")
}

fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.6}")
    } else {
        "null".to_string()
    }
}

fn write_json(records: &[Record]) -> std::io::Result<()> {
    let mut out = String::from("{\n  \"results\": [\n");
    for (i, r) in records.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"leg\": \"{}\", \"connections\": {}, \"workers\": {}, \
             \"queue_depth\": {}, \"sent\": {}, \"answered\": {}, \
             \"shed\": {}, \"p50_ms\": {}, \"p99_ms\": {}, \"qps\": {}}}{}\n",
            r.label,
            r.connections,
            r.workers,
            r.queue_depth,
            r.sent,
            r.answered,
            r.shed,
            json_num(r.p50_ms),
            json_num(r.p99_ms),
            json_num(r.qps),
            if i + 1 < records.len() { "," } else { "" },
        ));
    }
    out.push_str("  ]\n}\n");
    std::fs::write("BENCH_serve.json", out)
}

fn main() {
    let smoke = parse_args();
    let (m, n, queries) = if smoke { (200, 64, 50) } else { (1000, 256, 200) };
    let ds = two_gaussians(m, n, 8.min(n), 1.5, 17);
    let cfg = SelectionConfig::builder().k(8).lambda(1.0).build();
    let result = greedy_rls::select::run_to_completion(
        GreedyRls.begin(&ds.x, &ds.y, &cfg).expect("begin selection"),
    )
    .expect("selection");
    let server = Arc::new(HotSwapServer::new(result.predictor()));
    server.swap(result.predictor(), result.selected.len());

    let mut legs = vec![Leg {
        label: "throughput",
        connections: 2,
        workers: 2,
        queue_depth: 2,
        worker_delay: Duration::ZERO,
    }];
    if !smoke {
        legs.insert(
            0,
            Leg {
                label: "throughput",
                connections: 1,
                workers: 2,
                queue_depth: 2,
                worker_delay: Duration::ZERO,
            },
        );
        legs.push(Leg {
            label: "throughput",
            connections: 4,
            workers: 2,
            queue_depth: 2,
            worker_delay: Duration::ZERO,
        });
    }
    legs.push(Leg {
        label: "saturated",
        connections: 4,
        workers: 1,
        queue_depth: 1,
        worker_delay: Duration::from_millis(5),
    });

    let mut table = Table::new(
        "Serving fabric — listen front under load",
        &[
            "leg", "conns", "workers", "depth", "sent", "answered",
            "shed", "p50_ms", "p99_ms", "qps",
        ],
    );
    let mut records = Vec::new();
    for (i, leg) in legs.iter().enumerate() {
        let sock = std::env::temp_dir()
            .join(format!("grls-bench-{}-{i}.sock", std::process::id()));
        let _ = std::fs::remove_file(&sock);
        let addr = Addr::parse(&format!("unix:{}", sock.display()))
            .expect("bench socket addr");
        let front = ListenServer::spawn(
            &addr,
            Arc::clone(&server),
            ListenOptions {
                workers: leg.workers,
                queue_depth: leg.queue_depth,
                retry_after_ms: 5,
                worker_delay: leg.worker_delay,
                fabric: FabricOptions::default(),
            },
        )
        .expect("spawn listen front");
        let report = run_load(
            &addr,
            &ds.x,
            &LoadOptions {
                connections: leg.connections,
                queries_per_conn: queries,
                batch: 16,
                qps: 0.0,
                seed: 42,
                fabric: FabricOptions::default(),
            },
        )
        .expect("load run");
        drop(front);
        let _ = std::fs::remove_file(&sock);
        records.push(Record {
            label: leg.label,
            connections: leg.connections,
            workers: leg.workers,
            queue_depth: leg.queue_depth,
            sent: report.sent,
            answered: report.answered,
            shed: report.shed,
            p50_ms: report.p50_ms,
            p99_ms: report.p99_ms,
            qps: report.achieved_qps,
        });
        let r = records.last().expect("just pushed");
        table.row(&Table::cells(&[
            CellValue::Str(r.label.to_string()),
            CellValue::Usize(r.connections),
            CellValue::Usize(r.workers),
            CellValue::Usize(r.queue_depth),
            CellValue::Usize(r.sent as usize),
            CellValue::Usize(r.answered as usize),
            CellValue::Usize(r.shed as usize),
            CellValue::F3(r.p50_ms),
            CellValue::F3(r.p99_ms),
            CellValue::F3(r.qps),
        ]));
    }
    table.print();
    let _ = table.write_csv("serve_load");
    match write_json(&records) {
        Ok(()) => println!("\nmachine-readable: BENCH_serve.json"),
        Err(e) => eprintln!("\nfailed to write BENCH_serve.json: {e}"),
    }
    println!(
        "every query crosses the wire format (checksummed frames over a \
         unix socket); the saturated leg sheds with explicit retry-after \
         instead of queueing latency, so p99 stays bounded by design."
    );
}
