//! PJRT integration: the AOT artifacts must reproduce the native engine.
//!
//! These tests require `make artifacts` to have produced
//! `artifacts/manifest.tsv`; they are skipped (with a note) otherwise so
//! `cargo test` stays runnable on a fresh checkout.

use greedy_rls::coordinator::{self, serve, EngineKind};
use greedy_rls::data::synthetic;
use greedy_rls::metrics::Loss;
use greedy_rls::proptest::assert_close;
use greedy_rls::runtime::{
    engine::{PjrtBackward, PjrtFloating, PjrtFoba, PjrtGreedy, PjrtNFold},
    Runtime,
};
use greedy_rls::select::{
    backward::BackwardElimination, checkpoint, floating::FloatingForward,
    foba::Foba, greedy::GreedyRls, nfold::NFoldGreedy, run_to_completion,
    SelectionConfig, SelectionResult, Selector, SessionSelector,
};

fn runtime() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.tsv").exists() {
        eprintln!("skipping PJRT test: artifacts not built");
        return None;
    }
    Some(Runtime::open("artifacts").expect("runtime"))
}

#[test]
fn buckets_are_discovered() {
    let Some(rt) = runtime() else { return };
    let buckets = rt.selection_buckets();
    assert!(!buckets.is_empty());
    // ascending area, all complete
    for w in buckets.windows(2) {
        assert!(w[0].0 * w[0].1 <= w[1].0 * w[1].1);
    }
    assert_eq!(rt.pick_bucket(1, 1), Some(buckets[0]));
    assert_eq!(rt.pick_bucket(100_000, 1), None);
}

#[test]
fn pjrt_engine_matches_native_exactly() {
    let Some(rt) = runtime() else { return };
    // sizes chosen to exercise different buckets + nontrivial padding
    for (m, n, k, lam) in [
        (20usize, 12usize, 4usize, 0.5f64),
        (64, 128, 6, 1.0),   // exact bucket fit
        (65, 100, 5, 2.0),   // forces the next bucket up
        (200, 40, 8, 0.1),
    ] {
        let ds = synthetic::two_gaussians(m, n, (n / 4).max(1), 1.5, m as u64);
        for loss in [Loss::ZeroOne, Loss::Squared] {
            let cfg = SelectionConfig { k, lambda: lam, loss, ..Default::default() };
            let native = GreedyRls.select(&ds.x, &ds.y, &cfg).unwrap();
            let pjrt = PjrtGreedy::new(&rt).select(&ds.x, &ds.y, &cfg).unwrap();
            assert_eq!(
                native.selected, pjrt.selected,
                "m={m} n={n} loss={loss:?}"
            );
            assert_close(&native.weights, &pjrt.weights, 1e-8, "weights");
            for (a, b) in native.rounds.iter().zip(&pjrt.rounds) {
                assert!(
                    (a.criterion - b.criterion).abs()
                        <= 1e-8 * a.criterion.abs().max(1.0),
                    "criterion {} vs {}",
                    a.criterion,
                    b.criterion
                );
            }
        }
    }
}

#[test]
fn executables_are_cached() {
    let Some(rt) = runtime() else { return };
    let before = rt.compiled_count();
    let (m, n) = rt.selection_buckets()[0];
    let _a = rt.executable("score_step", m, n).unwrap();
    let _b = rt.executable("score_step", m, n).unwrap();
    assert_eq!(rt.compiled_count(), before + 1);
}

#[test]
fn missing_artifact_is_an_error() {
    let Some(rt) = runtime() else { return };
    assert!(rt.executable("score_step", 3, 3).is_err());
    assert!(rt.executable("nonexistent_entry", 64, 128).is_err());
}

#[test]
fn pjrt_serving_matches_native_serving() {
    let Some(rt) = runtime() else { return };
    let ds = synthetic::two_gaussians(150, 30, 6, 1.5, 77);
    let cfg = SelectionConfig { k: 6, lambda: 1.0, loss: Loss::ZeroOne, ..Default::default() };
    let p = coordinator::fit(EngineKind::Native, None, &ds, &cfg).unwrap();
    let (native_preds, _) = serve::serve_native(&p, &ds.x, 32).unwrap();
    let (pjrt_preds, stats) = serve::serve_pjrt(&rt, &p, &ds.x, 32).unwrap();
    assert_eq!(stats.requests, 150);
    assert_close(&native_preds, &pjrt_preds, 1e-9, "serving preds");
}

#[test]
fn select_with_engine_dispatches_to_pjrt() {
    let Some(rt) = runtime() else { return };
    let ds = synthetic::two_gaussians(40, 16, 4, 1.5, 5);
    let cfg = SelectionConfig { k: 3, lambda: 1.0, loss: Loss::ZeroOne, ..Default::default() };
    let r = coordinator::select_with_engine(
        EngineKind::Pjrt,
        Some(&rt),
        &ds.x,
        &ds.y,
        &cfg,
    )
    .unwrap();
    let native = GreedyRls.select(&ds.x, &ds.y, &cfg).unwrap();
    assert_eq!(r.selected, native.selected);
}

#[test]
fn pjrt_session_and_warm_start_match_one_shot() {
    let Some(rt) = runtime() else { return };
    let ds = synthetic::two_gaussians(48, 20, 5, 1.5, 13);
    let cfg = SelectionConfig {
        k: 5,
        lambda: 1.0,
        loss: Loss::ZeroOne,
        ..Default::default()
    };
    let engine = PjrtGreedy::new(&rt);
    let one_shot = engine.select(&ds.x, &ds.y, &cfg).unwrap();
    let stepped =
        run_to_completion(engine.begin(&ds.x, &ds.y, &cfg).unwrap()).unwrap();
    assert_eq!(one_shot.selected, stepped.selected);
    assert_eq!(one_shot.weights, stepped.weights);
    let resumed = run_to_completion(
        engine
            .begin_from(&ds.x, &ds.y, &cfg, &one_shot.selected[..2])
            .unwrap(),
    )
    .unwrap();
    assert_eq!(one_shot.selected, resumed.selected);
    assert_eq!(one_shot.weights, resumed.weights);
}

/// Native-vs-PJRT contract shared by every ported selector: identical
/// selected sets, criteria to relative tolerance (the artifact engines
/// solve with CG / incremental SMW where the native ones factor
/// directly), weights to the same tolerance.
fn assert_engine_parity(
    native: &SelectionResult,
    pjrt: &SelectionResult,
    tol: f64,
    what: &str,
) {
    assert_eq!(native.selected, pjrt.selected, "{what}: selected sets");
    assert_eq!(native.rounds.len(), pjrt.rounds.len(), "{what}: rounds");
    for (i, (a, b)) in native.rounds.iter().zip(&pjrt.rounds).enumerate() {
        assert_eq!(a.feature, b.feature, "{what}: round {i} feature");
        assert!(
            (a.criterion - b.criterion).abs()
                <= tol * a.criterion.abs().max(1.0),
            "{what}: round {i} criterion {} vs {}",
            a.criterion,
            b.criterion
        );
    }
    assert_close(&native.weights, &pjrt.weights, tol, what);
}

/// Every newly ported selector must reproduce its native engine across
/// thread counts {1, 2, 4} (threads exercise the native side — the PJRT
/// engine's parallelism lives in the compiled kernels) and both losses.
#[test]
fn ported_selectors_match_native_across_threads_and_losses() {
    let Some(rt) = runtime() else { return };
    let ds = synthetic::two_gaussians(60, 18, 5, 1.5, 31);
    let nfold = NFoldGreedy { folds: 5, seed: 11 };
    for loss in [Loss::ZeroOne, Loss::Squared] {
        for threads in [1usize, 2, 4] {
            let cfg = SelectionConfig {
                k: 5,
                lambda: 1.0,
                loss,
                threads,
                ..Default::default()
            };
            let what = format!("loss={loss:?} threads={threads}");
            assert_engine_parity(
                &BackwardElimination.select(&ds.x, &ds.y, &cfg).unwrap(),
                &PjrtBackward::new(&rt).select(&ds.x, &ds.y, &cfg).unwrap(),
                1e-6,
                &format!("backward {what}"),
            );
            assert_engine_parity(
                &nfold.select(&ds.x, &ds.y, &cfg).unwrap(),
                &PjrtNFold::with_params(&rt, nfold)
                    .select(&ds.x, &ds.y, &cfg)
                    .unwrap(),
                1e-6,
                &format!("nfold {what}"),
            );
            assert_engine_parity(
                &Foba::default().select(&ds.x, &ds.y, &cfg).unwrap(),
                &PjrtFoba::new(&rt).select(&ds.x, &ds.y, &cfg).unwrap(),
                1e-6,
                &format!("foba {what}"),
            );
            assert_engine_parity(
                &FloatingForward::default()
                    .select(&ds.x, &ds.y, &cfg)
                    .unwrap(),
                &PjrtFloating::new(&rt).select(&ds.x, &ds.y, &cfg).unwrap(),
                1e-6,
                &format!("floating {what}"),
            );
        }
    }
}

/// Backward/nfold sessions warm-start bit-identically to their own
/// uninterrupted runs (the begin_from replay path on artifact engines).
#[test]
fn ported_selector_sessions_warm_start() {
    let Some(rt) = runtime() else { return };
    let ds = synthetic::two_gaussians(48, 16, 4, 1.5, 17);
    let cfg = SelectionConfig { k: 4, lambda: 1.0, loss: Loss::ZeroOne, ..Default::default() };

    let backward = PjrtBackward::new(&rt);
    let full = backward.select(&ds.x, &ds.y, &cfg).unwrap();
    // replay the first two *eliminations*
    let replay: Vec<usize> =
        full.rounds.iter().take(2).map(|r| r.feature).collect();
    let resumed = run_to_completion(
        backward.begin_from(&ds.x, &ds.y, &cfg, &replay).unwrap(),
    )
    .unwrap();
    assert_eq!(full.selected, resumed.selected);
    assert_eq!(full.weights, resumed.weights);

    let nfold = PjrtNFold::with_params(&rt, NFoldGreedy { folds: 4, seed: 3 });
    let full = nfold.select(&ds.x, &ds.y, &cfg).unwrap();
    let resumed = run_to_completion(
        nfold
            .begin_from(&ds.x, &ds.y, &cfg, &full.selected[..2])
            .unwrap(),
    )
    .unwrap();
    assert_eq!(full.selected, resumed.selected);
    assert_eq!(full.weights, resumed.weights);
}

/// Checkpoint kill/resume through a PJRT-backed session: snapshot a
/// partial run to disk, reload it into a fresh PJRT session, and demand
/// the uninterrupted trajectory.
#[test]
fn checkpoint_resume_through_pjrt_session() {
    let Some(rt) = runtime() else { return };
    let ds = synthetic::two_gaussians(48, 20, 5, 1.5, 23);
    let cfg = SelectionConfig { k: 5, lambda: 1.0, loss: Loss::ZeroOne, ..Default::default() };
    let full = PjrtGreedy::new(&rt).select(&ds.x, &ds.y, &cfg).unwrap();

    let fp = checkpoint::fingerprint(&ds.x, &ds.y, &cfg);
    let mut session = PjrtGreedy::new(&rt).begin(&ds.x, &ds.y, &cfg).unwrap();
    session.step().unwrap();
    session.step().unwrap();
    let ckpt = checkpoint::Checkpoint::from_session(session.as_ref(), fp)
        .unwrap();
    let dir = std::env::temp_dir().join("greedy_rls_pjrt_ckpt_test");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let path = checkpoint::checkpoint_path(&dir, 2);
    ckpt.save_atomic(&path).unwrap();

    let (resumed, restored) = coordinator::resume_with_engine(
        EngineKind::Pjrt,
        Some(&rt),
        &ds.x,
        &ds.y,
        &cfg,
        &path,
    )
    .unwrap();
    assert_eq!(restored.rounds.len(), 2);
    assert_eq!(resumed.rounds_done(), 2);
    let r = run_to_completion(resumed).unwrap();
    assert_eq!(r.selected, full.selected);
    assert_eq!(r.weights, full.weights);
    let _ = std::fs::remove_dir_all(&dir);
}

/// CV curves on the PJRT engine match the native protocol (tolerance on
/// accuracies is unnecessary: both engines pick identical feature sets,
/// and accuracies are counts).
#[test]
fn cv_on_pjrt_engine_matches_native() {
    let Some(rt) = runtime() else { return };
    let ds = synthetic::two_gaussians(60, 12, 4, 1.5, 41);
    let native = coordinator::cv::run_cv_opts(
        &ds,
        &coordinator::cv::CvOptions {
            folds: 2,
            k_max: 3,
            seed: 5,
            threads: 1,
            ..Default::default()
        },
        None,
    )
    .unwrap();
    let pjrt = coordinator::cv::run_cv_opts(
        &ds,
        &coordinator::cv::CvOptions {
            folds: 2,
            k_max: 3,
            seed: 5,
            threads: 1,
            engine: EngineKind::Pjrt,
            ..Default::default()
        },
        Some(&rt),
    )
    .unwrap();
    assert_eq!(native.ks, pjrt.ks);
    assert_eq!(native.lambdas, pjrt.lambdas);
    // accuracies are counts over identical selected sets; tolerance only
    // guards the astronomically-unlikely boundary prediction
    assert_close(&native.greedy_test, &pjrt.greedy_test, 1e-9, "greedy");
    assert_close(&native.random_test, &pjrt.random_test, 1e-9, "random");
}

/// Default (non-pjrt) builds: the stub runtime reports the missing
/// feature with a clear error once the manifest parses — the PJRT paths
/// fail loudly, never silently.
#[cfg(not(feature = "pjrt"))]
#[test]
fn stub_runtime_reports_missing_feature_clearly() {
    let dir = std::env::temp_dir().join("greedy_rls_stub_artifacts");
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("manifest.tsv"),
        "score_step\tscore_step_m64_n128.hlo.txt\tm=64\tn=128\n",
    )
    .unwrap();
    let err = Runtime::open(&dir).unwrap_err();
    assert!(
        format!("{err:#}").contains("built without the pjrt feature"),
        "unexpected stub error: {err:#}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn train_dual_artifact_matches_native_rls() {
    let Some(rt) = runtime() else { return };
    // find a train_dual bucket
    let Some(row) = rt
        .manifest()
        .iter()
        .find(|e| e.entry == "train_dual")
        .cloned()
    else {
        return;
    };
    let (kb, mb) = (row.dim1.1, row.dim2.1);
    let exe = rt.executable("train_dual", kb, mb).unwrap();
    // real problem strictly smaller than the bucket; padding exactness
    let k = kb - 3;
    let m = mb - 7;
    let ds = synthetic::two_gaussians(m, k, (k / 3).max(1), 1.2, 9);
    let lam = 0.7;
    // pad Xs (k × m) into (kb × mb), y into mb
    let mut xs = vec![0.0; kb * mb];
    for i in 0..k {
        xs[i * mb..i * mb + m].copy_from_slice(ds.x.row(i));
    }
    let mut y = vec![0.0; mb];
    y[..m].copy_from_slice(&ds.y);
    use greedy_rls::runtime::lit;
    let outs = Runtime::run_tuple(
        &exe,
        &[
            lit::mat_f64(&xs, kb, mb).unwrap(),
            lit::vec_f64(&y),
            lit::vec_f64(&[lam]),
        ],
    )
    .unwrap();
    let w = lit::to_vec_f64(&outs[0]).unwrap();
    let (w_native, _) = greedy_rls::rls::train_dual(&ds.x, &ds.y, lam);
    assert_close(&w[..k], &w_native, 1e-7, "train_dual weights");
    // padded weight rows must be exactly zero
    assert!(w[k..].iter().all(|&v| v.abs() < 1e-12));
}
