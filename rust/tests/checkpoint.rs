//! Checkpoint robustness + kill/resume equivalence (integration level).
//!
//! The contract under test is the acceptance criterion of the checkpoint
//! subsystem: a session killed after *any* round and resumed from its
//! latest checkpoint produces a bit-identical selected set, criterion
//! curve, and weights to an uninterrupted run — for every selector, and
//! across thread counts (a run checkpointed serially may resume on 4
//! threads). Plus the failure modes: truncated/corrupt files, version
//! mismatches, config/data fingerprint mismatches, and crash-leftover
//! `.tmp` files must all be handled loudly or ignored safely, never
//! resumed into a silently wrong trajectory.

use std::path::PathBuf;

use greedy_rls::data::synthetic;
use greedy_rls::linalg::Matrix;
use greedy_rls::metrics::Loss;
use greedy_rls::rls::kernel::Kernel;
use greedy_rls::select::checkpoint::{
    self, drive_checkpointed, resume_from_path, AutosavePolicy, Autosaver,
    Checkpoint,
};
use greedy_rls::select::{
    backward::BackwardElimination, centers::CenterSelector,
    floating::FloatingForward, foba::Foba, greedy::GreedyRls,
    lowrank::LowRankLsSvm, nfold::NFoldGreedy, random::RandomSelector,
    rankrls::GreedyRankRls, run_to_completion, wrapper::Wrapper,
    NoopObserver, SelectionConfig, SelectionResult, Selector,
    SessionSelector,
};

fn scratch_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("greedy_rls_ckpt_it_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn assert_bit_identical(a: &SelectionResult, b: &SelectionResult, what: &str) {
    assert_eq!(a.selected, b.selected, "{what}: selected");
    assert_eq!(a.rounds.len(), b.rounds.len(), "{what}: round count");
    for (i, (ra, rb)) in a.rounds.iter().zip(&b.rounds).enumerate() {
        assert_eq!(ra.feature, rb.feature, "{what}: round {i} feature");
        assert_eq!(
            ra.criterion.to_bits(),
            rb.criterion.to_bits(),
            "{what}: round {i} criterion {} vs {}",
            ra.criterion,
            rb.criterion
        );
    }
    for (i, (wa, wb)) in a.weights.iter().zip(&b.weights).enumerate() {
        assert_eq!(wa.to_bits(), wb.to_bits(), "{what}: weight {i}");
    }
}

/// Run `sel` to completion with autosave-every-round, then — for several
/// kill points and thread counts — resume from the on-disk checkpoint and
/// demand a bit-identical final result.
fn check_kill_resume<S: Selector + SessionSelector>(
    sel: &S,
    x: &Matrix,
    y: &[f64],
    cfg: &SelectionConfig,
) {
    let name = sel.name();
    let dir = scratch_dir(name);
    let one_shot = sel.select(x, y, cfg).unwrap();

    // the "recording" run: autosave after every round
    let fp = checkpoint::fingerprint(x, y, cfg);
    let mut session = sel.begin(x, y, cfg).unwrap();
    let mut saver =
        Autosaver::new(&dir, AutosavePolicy::default(), fp).unwrap();
    drive_checkpointed(session.as_mut(), &mut NoopObserver, &mut saver)
        .unwrap();
    let recorded = session.finish().unwrap();
    assert_bit_identical(&one_shot, &recorded, &format!("{name}: recorded"));

    let n = one_shot.rounds.len();
    assert!(n >= 1, "{name}: nothing selected");
    assert!(saver.saves >= n, "{name}: every round checkpointed");

    let mut cuts = vec![1, n / 2, n];
    cuts.sort_unstable();
    cuts.dedup();
    cuts.retain(|&c| c >= 1);
    for cut in cuts {
        let path = checkpoint::checkpoint_path(&dir, cut);
        assert!(path.exists(), "{name}: missing checkpoint at round {cut}");
        for threads in [1usize, 2, 4] {
            let tcfg = SelectionConfig { threads, ..*cfg };
            let (resumed_session, ckpt) =
                resume_from_path(sel, x, y, &tcfg, &path).unwrap();
            assert_eq!(ckpt.rounds.len(), cut, "{name}: replay length");
            assert_eq!(resumed_session.rounds_done(), cut);
            let resumed = run_to_completion(resumed_session).unwrap();
            assert_bit_identical(
                &one_shot,
                &resumed,
                &format!("{name}: killed at {cut}, resumed on {threads}t"),
            );
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn kill_resume_is_bit_identical_for_every_selector() {
    let ds = synthetic::two_gaussians(40, 12, 4, 1.5, 51);
    for loss in [Loss::Squared, Loss::ZeroOne] {
        let cfg = SelectionConfig {
            k: 4,
            lambda: 0.8,
            loss,
            ..Default::default()
        };
        check_kill_resume(&GreedyRls, &ds.x, &ds.y, &cfg);
        check_kill_resume(&Wrapper::shortcut(), &ds.x, &ds.y, &cfg);
        check_kill_resume(&LowRankLsSvm, &ds.x, &ds.y, &cfg);
        check_kill_resume(&RandomSelector { seed: 5 }, &ds.x, &ds.y, &cfg);
        check_kill_resume(&BackwardElimination, &ds.x, &ds.y, &cfg);
        check_kill_resume(&FloatingForward::default(), &ds.x, &ds.y, &cfg);
        check_kill_resume(&Foba::default(), &ds.x, &ds.y, &cfg);
        check_kill_resume(
            &NFoldGreedy { folds: 5, seed: 2 },
            &ds.x,
            &ds.y,
            &cfg,
        );
        check_kill_resume(&GreedyRankRls, &ds.x, &ds.y, &cfg);
        check_kill_resume(
            &CenterSelector { kernel: Kernel::Rbf { gamma: 0.7 } },
            &ds.x,
            &ds.y,
            &cfg,
        );
    }
}

/// A checkpoint recorded under N threads must resume under any other
/// thread count — the config hash deliberately excludes `threads`.
#[test]
fn checkpoints_resume_across_thread_counts() {
    let ds = synthetic::two_gaussians(50, 14, 5, 1.5, 52);
    let recorded_cfg = SelectionConfig {
        k: 5,
        lambda: 1.0,
        loss: Loss::ZeroOne,
        threads: 4,
        ..Default::default()
    };
    let dir = scratch_dir("xthreads");
    let fp = checkpoint::fingerprint(&ds.x, &ds.y, &recorded_cfg);
    let mut session = GreedyRls.begin(&ds.x, &ds.y, &recorded_cfg).unwrap();
    let mut saver =
        Autosaver::new(&dir, AutosavePolicy::default(), fp).unwrap();
    drive_checkpointed(session.as_mut(), &mut NoopObserver, &mut saver)
        .unwrap();
    let full = session.finish().unwrap();

    let serial_cfg = SelectionConfig { threads: 1, ..recorded_cfg };
    let path = checkpoint::checkpoint_path(&dir, 2);
    let (s, _) =
        resume_from_path(&GreedyRls, &ds.x, &ds.y, &serial_cfg, &path)
            .unwrap();
    let resumed = run_to_completion(s).unwrap();
    assert_bit_identical(&full, &resumed, "4t recording, 1t resume");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Record one complete autosaved run in a test-unique directory (tests
/// run concurrently — they must not share scratch space) and return the
/// problem plus the latest checkpoint's path.
fn setup_one_checkpoint(tag: &str) -> (TestProblem, PathBuf) {
    let ds = synthetic::two_gaussians(40, 12, 4, 1.5, 53);
    let cfg = SelectionConfig {
        k: 4,
        lambda: 0.8,
        loss: Loss::ZeroOne,
        ..Default::default()
    };
    let dir = scratch_dir(tag);
    let fp = checkpoint::fingerprint(&ds.x, &ds.y, &cfg);
    let mut session = GreedyRls.begin(&ds.x, &ds.y, &cfg).unwrap();
    let mut saver =
        Autosaver::new(&dir, AutosavePolicy::default(), fp).unwrap();
    drive_checkpointed(session.as_mut(), &mut NoopObserver, &mut saver)
        .unwrap();
    let path = checkpoint::latest_in_dir(&dir).unwrap().unwrap();
    (TestProblem { ds, cfg }, path)
}

struct TestProblem {
    ds: greedy_rls::data::Dataset,
    cfg: SelectionConfig,
}

#[test]
fn truncated_checkpoint_file_is_rejected_with_a_clear_error() {
    let (p, path) = setup_one_checkpoint("trunc");
    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::write(&path, &text[..text.len() / 2]).unwrap();
    let err = resume_from_path(&GreedyRls, &p.ds.x, &p.ds.y, &p.cfg, &path)
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("truncated") || msg.contains("corrupt"),
        "unhelpful error: {msg}"
    );
    let _ = std::fs::remove_dir_all(path.parent().unwrap());
}

#[test]
fn corrupt_checkpoint_file_is_rejected_with_a_clear_error() {
    let (p, path) = setup_one_checkpoint("corrupt");
    let mut bytes = std::fs::read(&path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] = bytes[mid].wrapping_add(1);
    std::fs::write(&path, &bytes).unwrap();
    let err = resume_from_path(&GreedyRls, &p.ds.x, &p.ds.y, &p.cfg, &path)
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("corrupt")
            || msg.contains("truncated")
            || msg.contains("expected"),
        "unhelpful error: {msg}"
    );
    let _ = std::fs::remove_dir_all(path.parent().unwrap());
}

#[test]
fn version_mismatch_is_refused() {
    let (p, path) = setup_one_checkpoint("version");
    // rewrite as a "v2" file with a valid checksum, so only the version
    // check can reject it
    let text = std::fs::read_to_string(&path).unwrap();
    let bumped = text.replacen("checkpoint v1", "checkpoint v2", 1);
    let marker = bumped.rfind("\nend ").unwrap();
    let body = &bumped[..marker + 1];
    let mut h = greedy_rls::data::fingerprint::Fnv64::new();
    h.write(body.as_bytes());
    std::fs::write(&path, format!("{body}end {:016x}\n", h.finish()))
        .unwrap();
    let err = resume_from_path(&GreedyRls, &p.ds.x, &p.ds.y, &p.cfg, &path)
        .unwrap_err();
    assert!(
        format!("{err:#}").contains("unsupported checkpoint version"),
        "{err:#}"
    );
    let _ = std::fs::remove_dir_all(path.parent().unwrap());
}

#[test]
fn config_hash_mismatch_is_refused() {
    let (p, path) = setup_one_checkpoint("confmis");
    let other = SelectionConfig { lambda: 0.9, ..p.cfg };
    let err = resume_from_path(&GreedyRls, &p.ds.x, &p.ds.y, &other, &path)
        .unwrap_err();
    assert!(format!("{err:#}").contains("config hash"), "{err:#}");
    // a different thread count is NOT a config mismatch
    let threads = SelectionConfig { threads: 3, ..p.cfg };
    assert!(
        resume_from_path(&GreedyRls, &p.ds.x, &p.ds.y, &threads, &path)
            .is_ok()
    );
    let _ = std::fs::remove_dir_all(path.parent().unwrap());
}

#[test]
fn data_hash_mismatch_is_refused() {
    let (p, path) = setup_one_checkpoint("datamis");
    let other = synthetic::two_gaussians(40, 12, 4, 1.5, 54);
    let err = resume_from_path(&GreedyRls, &other.x, &other.y, &p.cfg, &path)
        .unwrap_err();
    assert!(format!("{err:#}").contains("data hash"), "{err:#}");
    let _ = std::fs::remove_dir_all(path.parent().unwrap());
}

/// Crash simulation around the atomic rename: a kill mid-save leaves a
/// `.tmp` sibling; the resume path must ignore it and use the newest
/// complete checkpoint.
#[test]
fn leftover_tmp_from_a_crashed_save_is_ignored() {
    let (p, path) = setup_one_checkpoint("tmpleft");
    let dir = path.parent().unwrap().to_path_buf();
    // a torn write the instant before rename: half a checkpoint under
    // the temporary name the saver uses
    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::write(
        dir.join("ckpt-00000099.ckpt.tmp"),
        &text[..text.len() / 3],
    )
    .unwrap();
    let latest = checkpoint::latest_in_dir(&dir).unwrap().unwrap();
    assert_eq!(latest, path, "tmp leftover must not win");
    let ckpt = Checkpoint::load(&latest).unwrap();
    assert_eq!(ckpt.rounds.len(), p.cfg.k);
    let (s, _) =
        resume_from_path(&GreedyRls, &p.ds.x, &p.ds.y, &p.cfg, &latest)
            .unwrap();
    let resumed = run_to_completion(s).unwrap();
    let reference = GreedyRls.select(&p.ds.x, &p.ds.y, &p.cfg).unwrap();
    assert_bit_identical(&reference, &resumed, "resume beside tmp leftover");
    let _ = std::fs::remove_dir_all(&dir);
}
