//! Kernel-tier equivalence — the dispatch surface must be invisible in
//! the numbers (integration level).
//!
//! The kernel contract (ARCHITECTURE.md §Compute kernels): every
//! *(kernel, precision)* pair is bit-deterministic across thread counts,
//! tile widths, and data backends; the `(Simd, F64)` pair is
//! additionally bit-identical to the `(Scalar, F64)` reference; and the
//! `(Scalar, F32c)` pair follows a different trajectory that is
//! tolerance-gated against f64 (EXPERIMENTS.md §Mixed precision) and
//! fenced off from every engine that does not implement it.
//!
//! The `#[cfg(feature = "simd")]` half of this suite is the pin that
//! keeps the nightly SIMD build honest: it runs whole selector
//! trajectories with the kernel forced to scalar and compares them
//! bitwise against the build's active (SIMD) dispatch, on both the
//! in-RAM and the stored backend.

use greedy_rls::data::storage::{MatrixStore, StorageOptions};
use greedy_rls::data::synthetic;
use greedy_rls::kernel::{KernelKind, Precision};
use greedy_rls::metrics::Loss;
use greedy_rls::select::backward::BackwardElimination;
use greedy_rls::select::checkpoint::config_hash;
use greedy_rls::select::floating::FloatingForward;
use greedy_rls::select::foba::Foba;
use greedy_rls::select::greedy::{GreedyRls, GreedyState};
use greedy_rls::select::nfold::NFoldGreedy;
use greedy_rls::select::{
    argmin, run_to_completion, SelectionConfig, SelectionResult, Selector,
};

fn assert_bit_identical(a: &SelectionResult, b: &SelectionResult, what: &str) {
    assert_eq!(a.selected, b.selected, "{what}: selected");
    assert_eq!(a.rounds.len(), b.rounds.len(), "{what}: round count");
    for (i, (ra, rb)) in a.rounds.iter().zip(&b.rounds).enumerate() {
        assert_eq!(ra.feature, rb.feature, "{what}: round {i} feature");
        assert_eq!(
            ra.criterion.to_bits(),
            rb.criterion.to_bits(),
            "{what}: round {i} criterion {} vs {}",
            ra.criterion,
            rb.criterion
        );
    }
    for (i, (wa, wb)) in a.weights.iter().zip(&b.weights).enumerate() {
        assert_eq!(wa.to_bits(), wb.to_bits(), "{what}: weight {i}");
    }
}

/// Every scan-based selector must produce bit-identical trajectories at
/// threads {1, 2, 4} on whatever kernel this build dispatches — the
/// per-(kernel, precision) determinism half of the contract. (The
/// default CI build runs this on the scalar reference; the nightly
/// `--features simd` job runs the identical test on the lane kernels.)
#[test]
fn selectors_bit_identical_across_threads_on_the_active_kernel() {
    let ds = synthetic::two_gaussians(48, 12, 4, 1.2, 17);
    let selectors: Vec<Box<dyn Selector>> = vec![
        Box::new(GreedyRls),
        Box::new(BackwardElimination),
        Box::new(NFoldGreedy::default()),
        Box::new(Foba::default()),
        Box::new(FloatingForward::default()),
    ];
    for sel in &selectors {
        for loss in [Loss::Squared, Loss::ZeroOne] {
            let base = SelectionConfig::builder()
                .k(4)
                .lambda(1.0)
                .loss(loss)
                .threads(1)
                .build();
            let serial = sel.select(&ds.x, &ds.y, &base).unwrap();
            for threads in [2usize, 4] {
                let cfg = base.with().threads(threads).build();
                let par = sel.select(&ds.x, &ds.y, &cfg).unwrap();
                assert_bit_identical(
                    &serial,
                    &par,
                    &format!("{} t={threads} {loss:?}", sel.name()),
                );
            }
        }
    }
}

/// `--precision f32c` on the greedy selector: deterministic across
/// thread counts (bit-identical), and tolerance-gated against the f64
/// trajectory — same selected features on a well-conditioned problem,
/// per-round criteria within the 1e-4 relative gate documented in
/// EXPERIMENTS.md §Mixed precision.
#[test]
fn f32c_session_is_deterministic_and_tracks_f64() {
    let ds = synthetic::two_gaussians(90, 18, 5, 1.0, 41);
    let f64_cfg = SelectionConfig::builder()
        .k(5)
        .lambda(1.0)
        .loss(Loss::Squared)
        .threads(1)
        .build();
    let f32_cfg = f64_cfg.with().precision(Precision::F32c).build();
    let exact = GreedyRls.select(&ds.x, &ds.y, &f64_cfg).unwrap();
    let mixed = GreedyRls.select(&ds.x, &ds.y, &f32_cfg).unwrap();
    for threads in [2usize, 4] {
        let par = GreedyRls
            .select(&ds.x, &ds.y, &f32_cfg.with().threads(threads).build())
            .unwrap();
        assert_bit_identical(&mixed, &par, &format!("f32c t={threads}"));
    }
    assert_eq!(exact.selected, mixed.selected, "selection diverged");
    for (i, (re, rm)) in exact.rounds.iter().zip(&mixed.rounds).enumerate() {
        let rel = (re.criterion - rm.criterion).abs()
            / re.criterion.abs().max(1.0);
        assert!(
            rel <= 1e-4,
            "round {i}: criterion rel err {rel} above the documented gate"
        );
    }
}

/// The precision knob is fenced: every selector but in-RAM greedy, and
/// the stored backend, must reject f32c at `begin` — and the checkpoint
/// config fingerprint must separate the two precisions so their
/// checkpoints can never silently resume each other.
#[test]
fn f32c_is_fenced_to_the_inram_greedy_engine() {
    let ds = synthetic::two_gaussians(30, 8, 3, 1.0, 5);
    let cfg = SelectionConfig::builder()
        .k(3)
        .precision(Precision::F32c)
        .build();
    let rejecting: Vec<Box<dyn Selector>> = vec![
        Box::new(BackwardElimination),
        Box::new(NFoldGreedy::default()),
        Box::new(Foba::default()),
        Box::new(FloatingForward::default()),
    ];
    for sel in &rejecting {
        let err = sel.select(&ds.x, &ds.y, &cfg).unwrap_err();
        assert!(
            err.to_string().contains("f32c"),
            "{}: {err}",
            sel.name()
        );
    }
    let opts = StorageOptions::default();
    let store = MatrixStore::from_matrix(&ds.x, &opts).unwrap();
    let err = GreedyRls
        .begin_stored(store, ds.y.clone(), &cfg, &opts)
        .unwrap_err();
    assert!(err.to_string().contains("f32c"), "stored: {err}");
    // and the one engine that accepts it fingerprints it distinctly
    let f64_cfg = cfg.with().precision(Precision::F64).build();
    assert_ne!(config_hash(&cfg), config_hash(&f64_cfg));
    assert!(GreedyRls.select(&ds.x, &ds.y, &cfg).is_ok());
}

/// Drive a raw [`GreedyState`] with an explicitly chosen kernel through
/// `k` rounds, returning (selected, criterion bits).
fn state_trajectory(
    ds: &greedy_rls::data::Dataset,
    kind: Option<KernelKind>,
    threads: usize,
    loss: Loss,
    k: usize,
) -> (Vec<usize>, Vec<u64>) {
    let mut st =
        GreedyState::init(&ds.x, &ds.y, 1.0).with_threads(threads);
    if let Some(kind) = kind {
        st = st.with_kernel(kind);
    }
    let mut crits = Vec::new();
    for _ in 0..k {
        let scores = st.score_all(&ds.x, &ds.y, loss);
        let b = argmin(&scores).unwrap();
        crits.push(scores[b].to_bits());
        st.commit(&ds.x, b);
    }
    (st.selected.clone(), crits)
}

/// Forcing the scalar kernel must never change anything relative to the
/// build's active dispatch. In the default build this is trivially true
/// (active == scalar); under `--features simd` it is the full-trajectory
/// SIMD-vs-scalar bit-identity pin, across thread counts and losses.
#[test]
fn active_kernel_matches_forced_scalar_bitwise() {
    let ds = synthetic::two_gaussians(64, 15, 5, 1.1, 23);
    for loss in [Loss::Squared, Loss::ZeroOne] {
        let reference =
            state_trajectory(&ds, Some(KernelKind::Scalar), 1, loss, 5);
        for threads in [1usize, 2, 4] {
            let active = state_trajectory(&ds, None, threads, loss, 5);
            assert_eq!(reference, active, "t={threads} {loss:?}");
        }
    }
}

/// The stored (out-of-core capable) engine runs the build's active
/// kernel too; its trajectory must match the forced-scalar in-RAM
/// reference bitwise — under `--features simd` this pins the SIMD tiled
/// kernels through the second backend.
#[test]
fn stored_backend_matches_forced_scalar_reference() {
    let ds = synthetic::two_gaussians(52, 13, 4, 1.3, 31);
    for loss in [Loss::Squared, Loss::ZeroOne] {
        let (sel_ref, crit_ref) =
            state_trajectory(&ds, Some(KernelKind::Scalar), 2, loss, 4);
        let cfg = SelectionConfig::builder()
            .k(4)
            .lambda(1.0)
            .loss(loss)
            .threads(2)
            .build();
        let opts = StorageOptions::default();
        let store = MatrixStore::from_matrix(&ds.x, &opts).unwrap();
        let stored = run_to_completion(
            GreedyRls.begin_stored(store, ds.y.clone(), &cfg, &opts).unwrap(),
        )
        .unwrap();
        assert_eq!(stored.selected, sel_ref, "{loss:?}: selected");
        let crit_stored: Vec<u64> = stored
            .rounds
            .iter()
            .map(|r| r.criterion.to_bits())
            .collect();
        assert_eq!(crit_stored, crit_ref, "{loss:?}: criteria");
    }
}
