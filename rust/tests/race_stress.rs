//! Race-stress tests for the serving concurrency surface, written to run
//! both in the ordinary suite and under ThreadSanitizer in CI's nightly
//! gauntlet. Each test drives many threads through [`ModelBus`] /
//! [`HotSwapServer`] and asserts the invariants a torn read, missed
//! wakeup, or lost close notification would break:
//!
//! - every blocked `wait_newer` follower drains the final published
//!   version before observing `Closed` — close never strands a waiter
//!   and never races ahead of the last publish;
//! - a snapshot taken mid-swap is always internally consistent: its
//!   model, rounds, and version all describe the same publish, and the
//!   versions one reader observes never go backwards.
//!
//! Models are tagged so the assertions can detect tearing: version `v`
//! always carries `selected = [v]` / `weights = [v]`, making any
//! model/version mismatch visible from a single snapshot.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use greedy_rls::coordinator::serve::HotSwapServer;
use greedy_rls::coordinator::stream::{BusWait, ModelBus};
use greedy_rls::linalg::Matrix;
use greedy_rls::rls::Predictor;

/// A predictor whose contents encode the version it was published as.
fn tagged(v: u64) -> Predictor {
    Predictor { selected: vec![v as usize], weights: vec![v as f64] }
}

/// Assert a [`greedy_rls::coordinator::serve::ModelVersion`] is not torn:
/// the model's tag must match the version number it rides with.
fn assert_coherent(v: &greedy_rls::coordinator::serve::ModelVersion) {
    assert_eq!(
        v.predictor.selected[0] as u64,
        v.version,
        "torn read: model selected-tag does not match its version"
    );
    assert_eq!(
        v.predictor.weights[0],
        v.version as f64,
        "torn read: model weight-tag does not match its version"
    );
}

/// Publish a burst of versions while several followers block in
/// `wait_newer`, then close the bus. Every follower must observe
/// strictly increasing, untorn versions, drain the final version, and
/// then see `Closed` — no waiter may hang or time out.
#[test]
fn bus_close_wakes_every_blocked_follower_after_final_drain() {
    const FOLLOWERS: usize = 8;
    const VERSIONS: u64 = 500;

    let bus = Arc::new(ModelBus::new());
    let handles: Vec<_> = (0..FOLLOWERS)
        .map(|_| {
            let bus = Arc::clone(&bus);
            std::thread::spawn(move || {
                let mut follower = bus.follower();
                let mut last = 0u64;
                loop {
                    match follower.wait_newer(Duration::from_secs(60)) {
                        BusWait::Newer(v) => {
                            assert!(
                                v.version > last,
                                "follower observed versions out of order"
                            );
                            assert_coherent(&v);
                            assert_eq!(
                                v.rounds as u64, v.version,
                                "rounds do not match the published version"
                            );
                            last = v.version;
                        }
                        BusWait::Closed => return last,
                        BusWait::TimedOut => {
                            panic!("blocked follower starved for 60s")
                        }
                    }
                }
            })
        })
        .collect();

    for v in 1..=VERSIONS {
        assert_eq!(bus.publish(tagged(v), v as usize), v);
        if v % 64 == 0 {
            // give waiters a chance to interleave with publishes
            std::thread::yield_now();
        }
    }
    bus.close();
    assert!(bus.is_closed());
    assert_eq!(bus.published(), VERSIONS);

    for h in handles {
        let last = h.join().unwrap();
        // Close never races ahead of the last publish: `Closed` is only
        // reported once nothing newer is left to drain, so every
        // follower's final observation is the final version.
        assert_eq!(
            last, VERSIONS,
            "follower saw Closed before draining the final version"
        );
    }
}

/// Followers that subscribe *after* publishing has started (and even
/// after close) still drain the latest version exactly once, then see
/// `Closed` immediately — the late-subscriber path of the same wakeup
/// machinery.
#[test]
fn bus_late_subscriber_drains_latest_then_closes() {
    let bus = ModelBus::new();
    for v in 1..=10u64 {
        bus.publish(tagged(v), v as usize);
    }
    bus.close();

    let mut follower = bus.follower();
    match follower.wait_newer(Duration::from_secs(60)) {
        BusWait::Newer(v) => {
            assert_eq!(v.version, 10, "latest-wins drain must skip to 10");
            assert_coherent(&v);
        }
        other => panic!("expected the final version first, got {other:?}"),
    }
    assert!(matches!(
        follower.wait_newer(Duration::from_millis(1)),
        BusWait::Closed
    ));
}

/// Hammer `swap` from one writer while reader threads take snapshots and
/// predict as fast as they can. Every snapshot must be internally
/// consistent (no torn model/version pair), versions must never move
/// backwards for any single reader, and predictions must match the
/// version that `predict_batch` reports they were computed with.
#[test]
fn hotswap_snapshots_never_tear_under_swap_load() {
    const READERS: usize = 6;
    const SWAPS: u64 = 4000;

    let server = Arc::new(HotSwapServer::new(tagged(0)));
    let stop = Arc::new(AtomicBool::new(false));

    let readers: Vec<_> = (0..READERS)
        .map(|r| {
            let server = Arc::clone(&server);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                // one-feature batch: model v predicts exactly v for a
                // unit input, so a prediction/version mismatch is a torn
                // read on the serving path itself
                let batch = Matrix::from_vec(1, 4, vec![1.0; 4]);
                let mut last = 0u64;
                let mut snapshots = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    let snap = server.snapshot();
                    assert_coherent(&snap);
                    assert!(
                        snap.version >= last,
                        "reader {r} observed a version rollback"
                    );
                    last = snap.version;
                    // only models on the 1-feature support can predict
                    // against the 1-row batch
                    if snap.version == 0 {
                        let (preds, ver) = server.predict_batch(&batch);
                        if ver == 0 {
                            assert_eq!(preds, [0.0; 4]);
                        }
                    }
                    snapshots += 1;
                }
                snapshots
            })
        })
        .collect();

    for i in 1..=SWAPS {
        // single writer: swap i publishes version i by construction
        assert_eq!(server.swap(tagged(i), i as usize), i);
    }
    stop.store(true, Ordering::Relaxed);

    for r in readers {
        let snapshots = r.join().unwrap();
        assert!(snapshots > 0, "reader made no progress under swap load");
    }
    assert_eq!(server.version(), SWAPS);
    let last = server.snapshot();
    assert_coherent(&last);
    assert_eq!(last.rounds as u64, SWAPS);
}

/// The prediction/version pairing under load, on a fixed support so
/// every model can score the same batch: model v has weight v on feature
/// 0, so `predict_batch` over a unit input must return exactly the
/// version it claims served the batch.
#[test]
fn hotswap_predictions_match_their_reported_version() {
    const SWAPS: u64 = 2000;
    const READERS: usize = 4;

    fn fixed_support(v: u64) -> Predictor {
        Predictor { selected: vec![0], weights: vec![v as f64] }
    }

    let server = Arc::new(HotSwapServer::new(fixed_support(0)));
    let stop = Arc::new(AtomicBool::new(false));

    let readers: Vec<_> = (0..READERS)
        .map(|_| {
            let server = Arc::clone(&server);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                let batch = Matrix::from_vec(1, 8, vec![1.0; 8]);
                while !stop.load(Ordering::Relaxed) {
                    let (preds, ver) = server.predict_batch(&batch);
                    // the whole batch was computed against one snapshot:
                    // every prediction equals the reported version
                    for p in &preds {
                        assert_eq!(
                            *p, ver as f64,
                            "batch mixes models: prediction disagrees \
                             with the version that reportedly served it"
                        );
                    }
                }
            })
        })
        .collect();

    for i in 1..=SWAPS {
        assert_eq!(server.swap(fixed_support(i), i as usize), i);
    }
    stop.store(true, Ordering::Relaxed);
    for r in readers {
        r.join().unwrap();
    }
    assert_eq!(server.version(), SWAPS);
}
