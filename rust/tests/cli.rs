//! CLI smoke tests: drive the built binary end-to-end via std::process.

use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_greedy-rls"))
}

fn run(args: &[&str]) -> (bool, String, String) {
    let out = bin().args(args).output().expect("spawn");
    (
        out.status.success(),
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
    )
}

#[test]
fn help_prints_usage() {
    let (ok, stdout, _) = run(&["help"]);
    assert!(ok);
    assert!(stdout.contains("greedy-rls"));
    assert!(stdout.contains("COMMANDS"));
}

#[test]
fn no_args_prints_usage() {
    let (ok, stdout, _) = run(&[]);
    assert!(ok);
    assert!(stdout.contains("USAGE"));
}

#[test]
fn unknown_command_fails() {
    let (ok, _, stderr) = run(&["frobnicate"]);
    assert!(!ok);
    assert!(stderr.contains("unknown command"));
}

#[test]
fn datasets_lists_table1() {
    let (ok, stdout, _) = run(&["datasets"]);
    assert!(ok);
    for name in ["adult", "australian", "colon-cancer", "german.numer",
                 "ijcnn1", "mnist5"] {
        assert!(stdout.contains(name), "missing {name}:\n{stdout}");
    }
    assert!(stdout.contains("32561"));
    assert!(stdout.contains("141691"));
}

#[test]
fn select_on_synthetic_and_save_model() {
    let tmp = std::env::temp_dir().join("greedy_rls_cli_model.txt");
    let _ = std::fs::remove_file(&tmp);
    let (ok, stdout, stderr) = run(&[
        "select",
        "--synthetic",
        "120,30",
        "--k",
        "5",
        "--lambda",
        "1.0",
        "--out",
        tmp.to_str().unwrap(),
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("selected (5)"), "{stdout}");
    let text = std::fs::read_to_string(&tmp).unwrap();
    assert!(text.starts_with("greedy-rls-model v1"));
    assert_eq!(text.lines().count(), 6); // header + 5 weights

    // and serve it back
    let (ok, stdout, stderr) = run(&[
        "serve",
        "--model",
        tmp.to_str().unwrap(),
        "--synthetic",
        "120,30",
        "--batch",
        "16",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("throughput"), "{stdout}");
    let _ = std::fs::remove_file(&tmp);
}

#[test]
fn select_on_registry_dataset() {
    let (ok, stdout, stderr) =
        run(&["select", "--dataset", "australian", "--k", "4"]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("dataset=australian"));
    assert!(stdout.contains("selected (4)"));
}

#[test]
fn select_rejects_bad_flags() {
    let (ok, _, stderr) = run(&["select", "--synthetic", "120"]);
    assert!(!ok);
    assert!(stderr.contains("M,N"), "{stderr}");
    let (ok, _, _) = run(&["select", "--dataset", "nope"]);
    assert!(!ok);
    let (ok, _, stderr) =
        run(&["select", "--synthetic", "20,5", "--k", "50"]);
    assert!(!ok);
    assert!(stderr.contains("k="), "{stderr}");
}

/// Extract N from a "selected (N): [...]" line.
fn selected_count(stdout: &str) -> usize {
    let line = stdout
        .lines()
        .find(|l| l.starts_with("selected ("))
        .unwrap_or_else(|| panic!("no selected line in:\n{stdout}"));
    line.trim_start_matches("selected (")
        .split(')')
        .next()
        .unwrap()
        .parse()
        .expect("selected count")
}

#[test]
fn plateau_stop_selects_fewer_features_on_overfitting_data() {
    // colon-cancer stand-in: m=62, n=2000 — the LOO criterion bottoms out
    // after a handful of features, so a plateau policy must stop well
    // before --k 40
    let (ok, stdout, stderr) = run(&[
        "select",
        "--dataset",
        "colon-cancer",
        "--k",
        "40",
        "--stop",
        "plateau",
        "--patience",
        "3",
    ]);
    assert!(ok, "stderr: {stderr}");
    let n_selected = selected_count(&stdout);
    assert!(
        n_selected < 40,
        "plateau should stop early, selected {n_selected}:\n{stdout}"
    );
    assert!(stdout.contains("criterion plateau"), "{stdout}");
}

#[test]
fn time_budget_zero_selects_nothing() {
    let (ok, stdout, stderr) = run(&[
        "select",
        "--synthetic",
        "60,20",
        "--k",
        "5",
        "--stop",
        "time",
        "--time-budget-s",
        "0",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert_eq!(selected_count(&stdout), 0, "{stdout}");
    assert!(stdout.contains("time budget"), "{stdout}");
}

#[test]
fn warm_start_pins_the_prefix() {
    let (ok, stdout, stderr) = run(&[
        "select",
        "--synthetic",
        "80,15",
        "--k",
        "4",
        "--warm-start",
        "7,2",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert_eq!(selected_count(&stdout), 4, "{stdout}");
    let line = stdout
        .lines()
        .find(|l| l.starts_with("selected ("))
        .unwrap();
    assert!(line.contains("[7, 2,"), "prefix not honored: {stdout}");
}

#[test]
fn bad_stop_flags_are_rejected() {
    let (ok, _, stderr) =
        run(&["select", "--synthetic", "60,20", "--stop", "banana"]);
    assert!(!ok);
    assert!(stderr.contains("--stop"), "{stderr}");
    let (ok, _, stderr) = run(&[
        "select",
        "--synthetic",
        "60,20",
        "--stop",
        "plateau",
        "--patience",
        "0",
    ]);
    assert!(!ok);
    assert!(stderr.contains("patience"), "{stderr}");
}

#[test]
fn cv_prints_curves() {
    let (ok, stdout, stderr) = run(&[
        "cv",
        "--dataset",
        "australian",
        "--folds",
        "3",
        "--kmax",
        "4",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("greedy_test"));
    // 4 data rows
    let rows = stdout
        .lines()
        .filter(|l| l.chars().next().is_some_and(|c| c.is_ascii_digit()))
        .count();
    assert_eq!(rows, 4, "{stdout}");
}

/// Regression (stop-clock accounting): a zero time budget must stop the
/// whole sweep — including the forced-order random baseline — instead of
/// panicking or running to kmax.
#[test]
fn cv_zero_time_budget_truncates_the_sweep() {
    let (ok, stdout, stderr) = run(&[
        "cv",
        "--synthetic",
        "80,10",
        "--folds",
        "2",
        "--kmax",
        "4",
        "--time-budget-s",
        "0",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("greedy_test"), "{stdout}");
    let rows = stdout
        .lines()
        .filter(|l| l.chars().next().is_some_and(|c| c.is_ascii_digit()))
        .count();
    assert_eq!(rows, 0, "zero budget must select nothing:\n{stdout}");
}

#[test]
fn cv_time_budget_with_checkpoints_is_rejected() {
    let dir = std::env::temp_dir().join("greedy_rls_cli_cv_tb");
    let _ = std::fs::remove_dir_all(&dir);
    let (ok, _, stderr) = run(&[
        "cv",
        "--synthetic",
        "60,8",
        "--folds",
        "2",
        "--kmax",
        "3",
        "--time-budget-s",
        "5",
        "--checkpoint-dir",
        dir.to_str().unwrap(),
    ]);
    assert!(!ok);
    assert!(stderr.contains("not checkpoint-resumable"), "{stderr}");
    let _ = std::fs::remove_dir_all(&dir);
}

/// `cv --engine pjrt` without artifacts reports the artifact/stub error
/// instead of silently running natively.
#[test]
fn cv_pjrt_engine_without_artifacts_errors() {
    if std::path::Path::new("artifacts/manifest.tsv").exists() {
        eprintln!("skipping: artifacts present, error path untestable");
        return;
    }
    let (ok, _, stderr) = run(&[
        "cv",
        "--synthetic",
        "40,6",
        "--folds",
        "2",
        "--engine",
        "pjrt",
    ]);
    assert!(!ok);
    assert!(
        stderr.contains("artifacts") || stderr.contains("pjrt feature"),
        "{stderr}"
    );
}

#[test]
fn scaling_prints_series() {
    let (ok, stdout, stderr) = run(&[
        "scaling",
        "--sizes",
        "100,200",
        "--n",
        "50",
        "--k",
        "5",
        "--baseline",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("lowrank_s"));
    let rows = stdout
        .lines()
        .filter(|l| l.chars().next().is_some_and(|c| c.is_ascii_digit()))
        .count();
    assert_eq!(rows, 2, "{stdout}");
}

#[test]
fn compare_runs_all_selectors() {
    let (ok, stdout, stderr) =
        run(&["compare", "--dataset", "australian", "--k", "3"]);
    assert!(ok, "stderr: {stderr}");
    for name in ["greedy-rls", "random", "foba", "nfold-greedy",
                 "lowrank-lssvm", "wrapper-shortcut",
                 "backward-elimination", "floating-forward"] {
        assert!(stdout.contains(name), "missing {name}:\n{stdout}");
    }
    // the LOO-equivalent selectors must agree on the selected set
    let greedy_line = stdout
        .lines()
        .find(|l| l.starts_with("greedy-rls"))
        .unwrap();
    let selected = greedy_line.split('\t').last().unwrap();
    for equiv in ["lowrank-lssvm", "wrapper-shortcut"] {
        let line = stdout.lines().find(|l| l.starts_with(equiv)).unwrap();
        assert!(line.ends_with(selected), "{equiv} disagreed:\n{stdout}");
    }
}

/// Regression (frontier accounting): `compare` must emit a well-formed
/// row for every selector even when a zero time budget truncates every
/// run at round 0 — empty-trajectory cells print "-" instead of
/// panicking or dropping the row.
#[test]
fn compare_zero_time_budget_emits_well_formed_table() {
    let (ok, stdout, stderr) = run(&[
        "compare",
        "--synthetic",
        "80,20",
        "--k",
        "3",
        "--stop",
        "time",
        "--time-budget-s",
        "0",
    ]);
    assert!(ok, "stderr: {stderr}");
    let header = stdout
        .lines()
        .find(|l| l.starts_with("selector\t"))
        .unwrap_or_else(|| panic!("no table header:\n{stdout}"));
    let columns = header.split('\t').count();
    assert_eq!(columns, 8, "unexpected header: {header}");
    let mut rows = 0;
    for name in ["greedy-rls", "sketched-greedy", "random", "foba",
                 "dropping-foba", "nfold-greedy"] {
        let line = stdout
            .lines()
            .find(|l| l.starts_with(name))
            .unwrap_or_else(|| panic!("missing {name}:\n{stdout}"));
        assert!(!line.contains("failed:"), "{line}");
        assert_eq!(
            line.split('\t').count(),
            columns,
            "ragged row: {line}"
        );
        rows += 1;
    }
    assert!(rows >= 2, "frontier needs at least two selectors");
}

/// `compare --preselect --json` writes the frontier artifact with both
/// sketched selectors in it.
#[test]
fn compare_preselect_writes_frontier_json() {
    let json = std::env::temp_dir().join("greedy_rls_cli_frontier.json");
    let _ = std::fs::remove_file(&json);
    let (ok, stdout, stderr) = run(&[
        "compare",
        "--synthetic",
        "80,20",
        "--k",
        "3",
        "--preselect",
        "8",
        "--sketch-dim",
        "4",
        "--json",
        json.to_str().unwrap(),
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("preselect_p=8"), "{stdout}");
    assert!(stdout.contains("sketch_dim=4"), "{stdout}");
    let text = std::fs::read_to_string(&json).unwrap();
    assert!(text.trim_start().starts_with('['), "{text}");
    assert!(text.trim_end().ends_with(']'), "{text}");
    for name in ["greedy-rls", "sketched-greedy", "dropping-foba"] {
        assert!(
            text.contains(&format!("\"selector\":\"{name}\"")),
            "missing {name} in:\n{text}"
        );
    }
    assert!(text.contains("\"scan_ops\":"), "{text}");
    let _ = std::fs::remove_file(&json);
}

#[test]
fn threads_flag_is_deterministic_end_to_end() {
    // the same problem at --threads 1, 2, 4 must print the identical
    // selected set and criterion trajectory (the CLI's determinism
    // guarantee), and the header must echo the resolved thread count
    let extract = |stdout: &str, prefix: &str| -> String {
        stdout
            .lines()
            .find(|l| l.starts_with(prefix))
            .unwrap_or_else(|| panic!("missing {prefix:?}:\n{stdout}"))
            .to_string()
    };
    let mut reference: Option<(String, String)> = None;
    for t in ["1", "2", "4"] {
        let (ok, stdout, stderr) = run(&[
            "select",
            "--synthetic",
            "90,23",
            "--k",
            "5",
            "--threads",
            t,
        ]);
        assert!(ok, "--threads {t} stderr: {stderr}");
        assert!(
            stdout.contains(&format!("threads={t}")),
            "--threads {t} not echoed:\n{stdout}"
        );
        let sel = extract(&stdout, "selected (5)");
        let curve = extract(&stdout, "criterion trajectory");
        match &reference {
            None => reference = Some((sel, curve)),
            Some((rs, rc)) => {
                assert_eq!(rs, &sel, "selected differ at --threads {t}");
                assert_eq!(rc, &curve, "curve differs at --threads {t}");
            }
        }
    }
}

/// Extract a line by prefix, panicking with the full output when absent.
fn extract_line(stdout: &str, prefix: &str) -> String {
    stdout
        .lines()
        .find(|l| l.starts_with(prefix))
        .unwrap_or_else(|| panic!("missing {prefix:?}:\n{stdout}"))
        .to_string()
}

/// The CLI half of the kill/resume contract: an interrupted checkpointed
/// run, resumed with `--resume`, prints the byte-identical selected set
/// and criterion trajectory of an uninterrupted run — including when the
/// interrupted half ran on a different thread count.
#[test]
fn checkpointed_resume_reproduces_uninterrupted_output() {
    let dir = std::env::temp_dir().join("greedy_rls_cli_ckpt_resume");
    let _ = std::fs::remove_dir_all(&dir);
    let problem =
        ["--synthetic", "120,30", "--k", "6", "--lambda", "1.0"];

    // uninterrupted reference
    let (ok, reference, stderr) =
        run(&[&["select"][..], &problem[..]].concat());
    assert!(ok, "stderr: {stderr}");
    let ref_sel = extract_line(&reference, "selected (6)");
    let ref_curve = extract_line(&reference, "criterion trajectory");

    // a full checkpointed recording; the "kill" is emulated below by
    // deleting every checkpoint past round 3 (the CI gauntlet does the
    // real SIGKILL variant of this test)
    let (ok, _, stderr) = run(&[
        &["select"][..],
        &problem[..],
        &["--checkpoint-dir", dir.to_str().unwrap()][..],
        &["--checkpoint-every", "1", "--threads", "2"][..],
    ]
    .concat());
    assert!(ok, "stderr: {stderr}");
    // simulate SIGKILL after round 3: drop every later checkpoint
    for rounds in 4..=6 {
        let f = dir.join(format!("ckpt-{rounds:08}.ckpt"));
        assert!(f.exists(), "expected {f:?}");
        std::fs::remove_file(f).unwrap();
    }

    // resume on a different thread count and compare the printed
    // selected set + criterion trajectory byte-for-byte
    let (ok, resumed, stderr) = run(&[
        &["select"][..],
        &problem[..],
        &["--checkpoint-dir", dir.to_str().unwrap()][..],
        &["--checkpoint-every", "1", "--resume", "--threads", "1"][..],
    ]
    .concat());
    assert!(ok, "stderr: {stderr}");
    assert!(
        resumed.contains("resumed from"),
        "no resume banner:\n{resumed}"
    );
    assert!(resumed.contains("3 rounds replayed"), "{resumed}");
    assert_eq!(ref_sel, extract_line(&resumed, "selected (6)"));
    assert_eq!(ref_curve, extract_line(&resumed, "criterion trajectory"));

    // --resume with an empty directory starts fresh and still matches
    let empty = std::env::temp_dir().join("greedy_rls_cli_ckpt_fresh");
    let _ = std::fs::remove_dir_all(&empty);
    std::fs::create_dir_all(&empty).unwrap();
    let (ok, fresh, stderr) = run(&[
        &["select"][..],
        &problem[..],
        &["--checkpoint-dir", empty.to_str().unwrap(), "--resume"][..],
    ]
    .concat());
    assert!(ok, "stderr: {stderr}");
    assert!(fresh.contains("starting fresh"), "{fresh}");
    assert_eq!(ref_sel, extract_line(&fresh, "selected (6)"));
    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&empty);
}

#[test]
fn checkpoint_flags_are_validated() {
    let (ok, _, stderr) =
        run(&["select", "--synthetic", "60,20", "--k", "3", "--resume"]);
    assert!(!ok);
    assert!(stderr.contains("--checkpoint-dir"), "{stderr}");
    let (ok, _, stderr) = run(&[
        "select",
        "--synthetic",
        "60,20",
        "--k",
        "3",
        "--checkpoint-every",
        "2",
    ]);
    assert!(!ok);
    assert!(stderr.contains("--checkpoint-dir"), "{stderr}");
}

#[test]
fn serve_follow_serves_the_latest_checkpoint() {
    // (the between-batch hot-swap itself is exercised deterministically
    // by the in-process serve_hotswap unit tests; a CLI-level mid-run
    // swap would need a racy concurrent writer)
    let dir = std::env::temp_dir().join("greedy_rls_cli_serve_follow");
    let _ = std::fs::remove_dir_all(&dir);
    let problem = ["--synthetic", "120,30", "--k", "5"];

    // produce a checkpoint trail with a finished model at the top
    let (ok, _, stderr) = run(&[
        &["select"][..],
        &problem[..],
        &["--checkpoint-dir", dir.to_str().unwrap()][..],
    ]
    .concat());
    assert!(ok, "stderr: {stderr}");

    // follow the directory: picks the latest checkpoint, serves, reports
    let (ok, stdout, stderr) = run(&[
        &["serve"][..],
        &["--follow", dir.to_str().unwrap()][..],
        &problem[..],
        &["--batch", "16", "--passes", "2", "--wait-s", "5"][..],
    ]
    .concat());
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("following"), "{stdout}");
    assert!(stdout.contains("swaps="), "{stdout}");
    assert!(stdout.contains("final_rounds=5"), "{stdout}");
    assert!(stdout.contains("throughput"), "{stdout}");

    // following with a mismatched dataset must fail loudly
    let (ok, _, stderr) = run(&[
        "serve",
        "--follow",
        dir.to_str().unwrap(),
        "--synthetic",
        "120,31",
        "--wait-s",
        "2",
    ]);
    assert!(!ok);
    assert!(stderr.contains("data hash"), "{stderr}");

    // an empty directory times out with a clear error
    let empty = std::env::temp_dir().join("greedy_rls_cli_serve_empty");
    let _ = std::fs::remove_dir_all(&empty);
    std::fs::create_dir_all(&empty).unwrap();
    let (ok, _, stderr) = run(&[
        "serve",
        "--follow",
        empty.to_str().unwrap(),
        "--synthetic",
        "120,30",
        "--wait-s",
        "0",
    ]);
    assert!(!ok);
    assert!(stderr.contains("no servable checkpoint"), "{stderr}");
    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&empty);
}

/// `train-serve` trains exactly like `select` (identical selected set
/// and criterion trajectory) while publishing ≥ k versions over the
/// in-process bus and serving a final deterministic pass.
#[test]
fn train_serve_matches_select_and_publishes_every_round() {
    let problem = ["--synthetic", "120,30", "--k", "5", "--lambda", "1.0"];
    let (ok, reference, stderr) =
        run(&[&["select"][..], &problem[..]].concat());
    assert!(ok, "stderr: {stderr}");
    let ref_sel = extract_line(&reference, "selected (5)");
    let ref_curve = extract_line(&reference, "criterion trajectory");

    let (ok, stdout, stderr) = run(&[
        &["train-serve"][..],
        &problem[..],
        &["--serve-threads", "2", "--batch", "16"][..],
    ]
    .concat());
    assert!(ok, "stderr: {stderr}");
    assert_eq!(ref_sel, extract_line(&stdout, "selected (5)"));
    assert_eq!(ref_curve, extract_line(&stdout, "criterion trajectory"));
    let published_line = extract_line(&stdout, "published=");
    let published: u64 = published_line
        .trim_start_matches("published=")
        .split(' ')
        .next()
        .unwrap()
        .parse()
        .expect("published count");
    assert!(published >= 5, "expected ≥ 5 versions: {published_line}");
    assert!(stdout.contains("final pass: accuracy="), "{stdout}");
    assert!(stdout.contains("version\trounds"), "{stdout}");
}

/// `serve --bus` is the train-serve pipeline; `--model`/`--follow`
/// conflict with it.
#[test]
fn serve_bus_aliases_train_serve() {
    let (ok, stdout, stderr) = run(&[
        "serve",
        "--bus",
        "--synthetic",
        "100,20",
        "--k",
        "4",
        "--batch",
        "32",
    ]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("published="), "{stdout}");
    assert!(stdout.contains("selected (4)"), "{stdout}");

    let (ok, _, stderr) = run(&[
        "serve",
        "--bus",
        "--model",
        "whatever.txt",
        "--synthetic",
        "100,20",
    ]);
    assert!(!ok);
    assert!(stderr.contains("--bus"), "{stderr}");
}

/// The CLI half of the train-serve kill/resume contract: a truncated
/// checkpoint trail resumed with `--resume` converges to the identical
/// selected set and criterion trajectory (CI's gauntlet runs the real
/// SIGKILL variant).
#[test]
fn train_serve_checkpoint_resume_reproduces_output() {
    let dir = std::env::temp_dir().join("greedy_rls_cli_ts_resume");
    let _ = std::fs::remove_dir_all(&dir);
    let problem = ["--synthetic", "120,30", "--k", "6", "--lambda", "1.0"];

    let base = [
        &["train-serve"][..],
        &problem[..],
        &["--serve-threads", "2", "--batch", "16"][..],
        &["--checkpoint-dir", dir.to_str().unwrap()][..],
        &["--checkpoint-every", "1"][..],
    ]
    .concat();
    let (ok, reference, stderr) = run(&base);
    assert!(ok, "stderr: {stderr}");
    let ref_sel = extract_line(&reference, "selected (6)");
    let ref_curve = extract_line(&reference, "criterion trajectory");

    // emulate a SIGKILL after round 3
    for rounds in 4..=6 {
        let f = dir.join(format!("ckpt-{rounds:08}.ckpt"));
        assert!(f.exists(), "expected {f:?}");
        std::fs::remove_file(f).unwrap();
    }
    let (ok, resumed, stderr) =
        run(&[&base[..], &["--resume"][..]].concat());
    assert!(ok, "stderr: {stderr}");
    assert!(resumed.contains("resumed from"), "{resumed}");
    assert_eq!(ref_sel, extract_line(&resumed, "selected (6)"));
    assert_eq!(ref_curve, extract_line(&resumed, "criterion trajectory"));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn train_serve_rejects_bad_flags() {
    let (ok, _, stderr) = run(&[
        "train-serve",
        "--synthetic",
        "60,20",
        "--k",
        "3",
        "--batch",
        "0",
    ]);
    assert!(!ok);
    assert!(stderr.contains("--batch"), "{stderr}");
    let (ok, _, stderr) =
        run(&["train-serve", "--synthetic", "60,20", "--k", "3", "--resume"]);
    assert!(!ok);
    assert!(stderr.contains("--checkpoint-dir"), "{stderr}");
}

#[test]
fn cv_checkpoint_dir_resumes_folds() {
    let dir = std::env::temp_dir().join("greedy_rls_cli_cv_ckpt");
    let _ = std::fs::remove_dir_all(&dir);
    let base = [
        "cv", "--dataset", "australian", "--folds", "3", "--kmax", "3",
    ];
    let (ok, reference, stderr) = run(&base);
    assert!(ok, "stderr: {stderr}");
    let (ok, cold, stderr) = run(&[
        &base[..],
        &["--checkpoint-dir", dir.to_str().unwrap()][..],
    ]
    .concat());
    assert!(ok, "stderr: {stderr}");
    assert_eq!(reference, cold, "fold checkpoints must not change output");
    // all folds cached: identical output again
    let (ok, warm, stderr) = run(&[
        &base[..],
        &["--checkpoint-dir", dir.to_str().unwrap()][..],
    ]
    .concat());
    assert!(ok, "stderr: {stderr}");
    assert_eq!(reference, warm);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn check_verifies_artifacts_when_present() {
    if !std::path::Path::new("artifacts/manifest.tsv").exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let (ok, stdout, stderr) = run(&["check"]);
    assert!(ok, "stderr: {stderr}");
    assert!(stdout.contains("artifacts OK"), "{stdout}");
    assert!(stdout.contains("engines agree"));
}
