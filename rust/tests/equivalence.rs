//! Cross-algorithm equivalence — the paper's central correctness claims,
//! exercised end-to-end across the library (integration level).
//!
//! Algorithms 1 (wrapper), 2 (low-rank LS-SVM) and 3 (greedy RLS) must
//! select identical feature sequences with identical criteria and final
//! weights on arbitrary problems, for both losses; the extensions must
//! honor their own contracts (n-fold → LOO degeneracy, backward ≥ greedy
//! criterion relationships are data-dependent so only structural checks).

use greedy_rls::data::synthetic;
use greedy_rls::metrics::Loss;
use greedy_rls::proptest::{assert_close, forall_seeds, Gen};
use greedy_rls::select::{
    backward::BackwardElimination, greedy::GreedyRls, lowrank::LowRankLsSvm,
    nfold::NFoldGreedy, random::RandomSelector, wrapper::Wrapper,
    SelectionConfig, Selector,
};

#[test]
fn all_three_algorithms_agree_on_random_problems() {
    forall_seeds(30, |seed| {
        let mut g = Gen::new(seed * 31 + 5);
        let n = g.size(4, 14);
        let m = g.size(4, 14);
        let k = 3.min(n);
        let lam = g.lambda(-2, 2);
        let x = g.matrix(n, m);
        let y = g.labels(m);
        for loss in [Loss::Squared, Loss::ZeroOne] {
            let cfg = SelectionConfig { k, lambda: lam, loss };
            let r1 = Wrapper::shortcut().select(&x, &y, &cfg).unwrap();
            let r2 = LowRankLsSvm.select(&x, &y, &cfg).unwrap();
            let r3 = GreedyRls.select(&x, &y, &cfg).unwrap();
            assert_eq!(r1.selected, r3.selected, "wrapper vs greedy");
            assert_eq!(r2.selected, r3.selected, "lowrank vs greedy");
            assert_close(&r1.weights, &r3.weights, 1e-6, "w1 vs w3");
            assert_close(&r2.weights, &r3.weights, 1e-6, "w2 vs w3");
        }
    });
}

#[test]
fn brute_force_wrapper_agrees_on_small_problems() {
    forall_seeds(8, |seed| {
        let mut g = Gen::new(seed * 17 + 3);
        let n = g.size(3, 6);
        let m = g.size(4, 8);
        let lam = g.lambda(-1, 1);
        let x = g.matrix(n, m);
        let y = g.targets(m);
        let cfg = SelectionConfig { k: 2, lambda: lam, loss: Loss::Squared };
        let rb = Wrapper::brute_force().select(&x, &y, &cfg).unwrap();
        let r3 = GreedyRls.select(&x, &y, &cfg).unwrap();
        assert_eq!(rb.selected, r3.selected);
        for (a, b) in rb.rounds.iter().zip(&r3.rounds) {
            assert!(
                (a.criterion - b.criterion).abs()
                    <= 1e-6 * a.criterion.abs().max(1.0)
            );
        }
    });
}

#[test]
fn greedy_dominates_random_on_benchmark_standins() {
    // On planted-sparse data with ample signal, the greedy test accuracy
    // at k = #informative must beat random selection's.
    for name in ["australian", "german.numer"] {
        let ds = greedy_rls::data::registry::load(name, false, 7).unwrap();
        let cfg = SelectionConfig { k: 5, lambda: 1.0, loss: Loss::ZeroOne };
        let rg = GreedyRls.select(&ds.x, &ds.y, &cfg).unwrap();
        let rr = RandomSelector { seed: 3 }.select(&ds.x, &ds.y, &cfg).unwrap();
        let pg = rg.predictor().predict_matrix(&ds.x);
        let pr = rr.predictor().predict_matrix(&ds.x);
        let ag = greedy_rls::metrics::accuracy(&ds.y, &pg);
        let ar = greedy_rls::metrics::accuracy(&ds.y, &pr);
        assert!(ag >= ar - 0.02, "{name}: greedy {ag} vs random {ar}");
    }
}

#[test]
fn nfold_with_m_folds_equals_greedy() {
    let ds = synthetic::two_gaussians(24, 10, 4, 1.5, 11);
    let cfg = SelectionConfig { k: 4, lambda: 0.8, loss: Loss::Squared };
    let r_loo = GreedyRls.select(&ds.x, &ds.y, &cfg).unwrap();
    let r_nf = NFoldGreedy { folds: 24, seed: 1 }
        .select(&ds.x, &ds.y, &cfg)
        .unwrap();
    assert_eq!(r_loo.selected, r_nf.selected);
}

#[test]
fn backward_and_forward_agree_on_unambiguous_support() {
    // When the signal is overwhelmingly concentrated on a small support,
    // forward and backward must land on the same feature set.
    let (ds, mut support) = synthetic::sparse_regression(250, 12, 3, 0.02, 19);
    let cfg = SelectionConfig { k: 3, lambda: 0.1, loss: Loss::Squared };
    let mut fwd = GreedyRls.select(&ds.x, &ds.y, &cfg).unwrap().selected;
    let mut bwd =
        BackwardElimination.select(&ds.x, &ds.y, &cfg).unwrap().selected;
    fwd.sort_unstable();
    bwd.sort_unstable();
    support.sort_unstable();
    assert_eq!(fwd, support);
    assert_eq!(bwd, support);
}

#[test]
fn selection_is_deterministic() {
    let ds = synthetic::two_gaussians(60, 20, 5, 1.0, 23);
    let cfg = SelectionConfig { k: 6, lambda: 1.0, loss: Loss::ZeroOne };
    let a = GreedyRls.select(&ds.x, &ds.y, &cfg).unwrap();
    let b = GreedyRls.select(&ds.x, &ds.y, &cfg).unwrap();
    assert_eq!(a.selected, b.selected);
    assert_eq!(a.weights, b.weights);
}

#[test]
fn criterion_trajectories_match_across_algorithms() {
    let mut g = Gen::new(404);
    let x = g.matrix(8, 10);
    let y = g.labels(10);
    let cfg = SelectionConfig { k: 4, lambda: 2.0, loss: Loss::ZeroOne };
    let r2 = LowRankLsSvm.select(&x, &y, &cfg).unwrap();
    let r3 = GreedyRls.select(&x, &y, &cfg).unwrap();
    let c2 = r2.criterion_curve();
    let c3 = r3.criterion_curve();
    assert_eq!(c2.len(), c3.len());
    for (a, b) in c2.iter().zip(&c3) {
        assert!((a - b).abs() < 1e-9, "{c2:?} vs {c3:?}");
    }
}
