//! Cross-algorithm equivalence — the paper's central correctness claims,
//! exercised end-to-end across the library (integration level).
//!
//! Algorithms 1 (wrapper), 2 (low-rank LS-SVM) and 3 (greedy RLS) must
//! select identical feature sequences with identical criteria and final
//! weights on arbitrary problems, for both losses; the extensions must
//! honor their own contracts (n-fold → LOO degeneracy, backward ≥ greedy
//! criterion relationships are data-dependent so only structural checks).

use greedy_rls::data::synthetic;
use greedy_rls::metrics::Loss;
use greedy_rls::proptest::{assert_close, forall_seeds, Gen};
use greedy_rls::rls::kernel::Kernel;
use greedy_rls::select::{
    backward::BackwardElimination, centers::CenterSelector,
    floating::FloatingForward, foba::Foba, greedy::GreedyRls,
    lowrank::LowRankLsSvm, nfold::NFoldGreedy, random::RandomSelector,
    rankrls::GreedyRankRls, run_to_completion, wrapper::Wrapper,
    SelectionConfig, SelectionResult, Selector, SessionSelector, StepOutcome,
};

#[test]
fn all_three_algorithms_agree_on_random_problems() {
    forall_seeds(30, |seed| {
        let mut g = Gen::new(seed * 31 + 5);
        let n = g.size(4, 14);
        let m = g.size(4, 14);
        let k = 3.min(n);
        let lam = g.lambda(-2, 2);
        let x = g.matrix(n, m);
        let y = g.labels(m);
        for loss in [Loss::Squared, Loss::ZeroOne] {
            let cfg = SelectionConfig { k, lambda: lam, loss, ..Default::default() };
            let r1 = Wrapper::shortcut().select(&x, &y, &cfg).unwrap();
            let r2 = LowRankLsSvm.select(&x, &y, &cfg).unwrap();
            let r3 = GreedyRls.select(&x, &y, &cfg).unwrap();
            assert_eq!(r1.selected, r3.selected, "wrapper vs greedy");
            assert_eq!(r2.selected, r3.selected, "lowrank vs greedy");
            assert_close(&r1.weights, &r3.weights, 1e-6, "w1 vs w3");
            assert_close(&r2.weights, &r3.weights, 1e-6, "w2 vs w3");
        }
    });
}

#[test]
fn brute_force_wrapper_agrees_on_small_problems() {
    forall_seeds(8, |seed| {
        let mut g = Gen::new(seed * 17 + 3);
        let n = g.size(3, 6);
        let m = g.size(4, 8);
        let lam = g.lambda(-1, 1);
        let x = g.matrix(n, m);
        let y = g.targets(m);
        let cfg = SelectionConfig { k: 2, lambda: lam, loss: Loss::Squared, ..Default::default() };
        let rb = Wrapper::brute_force().select(&x, &y, &cfg).unwrap();
        let r3 = GreedyRls.select(&x, &y, &cfg).unwrap();
        assert_eq!(rb.selected, r3.selected);
        for (a, b) in rb.rounds.iter().zip(&r3.rounds) {
            assert!(
                (a.criterion - b.criterion).abs()
                    <= 1e-6 * a.criterion.abs().max(1.0)
            );
        }
    });
}

#[test]
fn greedy_dominates_random_on_benchmark_standins() {
    // On planted-sparse data with ample signal, the greedy test accuracy
    // at k = #informative must beat random selection's.
    for name in ["australian", "german.numer"] {
        let ds = greedy_rls::data::registry::load(name, false, 7).unwrap();
        let cfg = SelectionConfig { k: 5, lambda: 1.0, loss: Loss::ZeroOne, ..Default::default() };
        let rg = GreedyRls.select(&ds.x, &ds.y, &cfg).unwrap();
        let rr = RandomSelector { seed: 3 }.select(&ds.x, &ds.y, &cfg).unwrap();
        let pg = rg.predictor().predict_matrix(&ds.x);
        let pr = rr.predictor().predict_matrix(&ds.x);
        let ag = greedy_rls::metrics::accuracy(&ds.y, &pg);
        let ar = greedy_rls::metrics::accuracy(&ds.y, &pr);
        assert!(ag >= ar - 0.02, "{name}: greedy {ag} vs random {ar}");
    }
}

#[test]
fn nfold_with_m_folds_equals_greedy() {
    let ds = synthetic::two_gaussians(24, 10, 4, 1.5, 11);
    let cfg = SelectionConfig { k: 4, lambda: 0.8, loss: Loss::Squared, ..Default::default() };
    let r_loo = GreedyRls.select(&ds.x, &ds.y, &cfg).unwrap();
    let r_nf = NFoldGreedy { folds: 24, seed: 1 }
        .select(&ds.x, &ds.y, &cfg)
        .unwrap();
    assert_eq!(r_loo.selected, r_nf.selected);
}

#[test]
fn backward_and_forward_agree_on_unambiguous_support() {
    // When the signal is overwhelmingly concentrated on a small support,
    // forward and backward must land on the same feature set.
    let (ds, mut support) = synthetic::sparse_regression(250, 12, 3, 0.02, 19);
    let cfg = SelectionConfig { k: 3, lambda: 0.1, loss: Loss::Squared, ..Default::default() };
    let mut fwd = GreedyRls.select(&ds.x, &ds.y, &cfg).unwrap().selected;
    let mut bwd =
        BackwardElimination.select(&ds.x, &ds.y, &cfg).unwrap().selected;
    fwd.sort_unstable();
    bwd.sort_unstable();
    support.sort_unstable();
    assert_eq!(fwd, support);
    assert_eq!(bwd, support);
}

// ---------------------------------------------------------------------------
// Session API equivalence: for every selector, driving a session
// step-by-step — and resuming a warm-started session — must yield a
// SelectionResult bit-identical to the one-shot `select`.
// ---------------------------------------------------------------------------

fn assert_bit_identical(a: &SelectionResult, b: &SelectionResult, what: &str) {
    assert_eq!(a.selected, b.selected, "{what}: selected");
    assert_eq!(a.rounds.len(), b.rounds.len(), "{what}: round count");
    for (i, (ra, rb)) in a.rounds.iter().zip(&b.rounds).enumerate() {
        assert_eq!(ra.feature, rb.feature, "{what}: round {i} feature");
        assert_eq!(
            ra.criterion.to_bits(),
            rb.criterion.to_bits(),
            "{what}: round {i} criterion {} vs {}",
            ra.criterion,
            rb.criterion
        );
    }
    assert_eq!(a.weights.len(), b.weights.len(), "{what}: weight count");
    for (i, (wa, wb)) in a.weights.iter().zip(&b.weights).enumerate() {
        assert_eq!(
            wa.to_bits(),
            wb.to_bits(),
            "{what}: weight {i} {wa} vs {wb}"
        );
    }
}

fn check_session_equivalence<S: Selector + SessionSelector>(
    sel: &S,
    x: &greedy_rls::linalg::Matrix,
    y: &[f64],
    cfg: &SelectionConfig,
) {
    let name = sel.name();
    let one_shot = sel.select(x, y, cfg).unwrap();

    // manual step-by-step drive
    let mut session = sel.begin(x, y, cfg).unwrap();
    loop {
        match session.step().unwrap() {
            StepOutcome::Selected(_) => {}
            StepOutcome::Done(_) => break,
        }
    }
    let stepped = session.finish().unwrap();
    assert_bit_identical(&one_shot, &stepped, &format!("{name}: stepwise"));

    // warm-start resume from several prefixes of the recorded rounds
    let replay: Vec<usize> =
        one_shot.rounds.iter().map(|r| r.feature).collect();
    let mut cuts = vec![1, replay.len() / 2, replay.len().saturating_sub(1)];
    cuts.sort_unstable();
    cuts.dedup();
    for j in cuts {
        if j > replay.len() {
            continue;
        }
        let session = sel.begin_from(x, y, cfg, &replay[..j]).unwrap();
        assert_eq!(session.rounds_done(), j, "{name}: warm start at {j}");
        let resumed = run_to_completion(session).unwrap();
        assert_bit_identical(
            &one_shot,
            &resumed,
            &format!("{name}: warm start at {j}"),
        );
    }
}

#[test]
fn sessions_match_one_shot_for_every_selector() {
    let ds = synthetic::two_gaussians(40, 12, 4, 1.5, 31);
    for loss in [Loss::Squared, Loss::ZeroOne] {
        let cfg = SelectionConfig {
            k: 4,
            lambda: 0.8,
            loss,
            ..Default::default()
        };
        check_session_equivalence(&GreedyRls, &ds.x, &ds.y, &cfg);
        check_session_equivalence(&Wrapper::shortcut(), &ds.x, &ds.y, &cfg);
        check_session_equivalence(&Wrapper::brute_force(), &ds.x, &ds.y, &cfg);
        check_session_equivalence(&LowRankLsSvm, &ds.x, &ds.y, &cfg);
        check_session_equivalence(
            &RandomSelector { seed: 5 },
            &ds.x,
            &ds.y,
            &cfg,
        );
        check_session_equivalence(&BackwardElimination, &ds.x, &ds.y, &cfg);
        check_session_equivalence(
            &FloatingForward::default(),
            &ds.x,
            &ds.y,
            &cfg,
        );
        check_session_equivalence(&Foba::default(), &ds.x, &ds.y, &cfg);
        check_session_equivalence(
            &NFoldGreedy { folds: 5, seed: 2 },
            &ds.x,
            &ds.y,
            &cfg,
        );
        check_session_equivalence(&GreedyRankRls, &ds.x, &ds.y, &cfg);
        check_session_equivalence(
            &CenterSelector { kernel: Kernel::Rbf { gamma: 0.7 } },
            &ds.x,
            &ds.y,
            &cfg,
        );
    }
}

#[test]
fn session_equivalence_holds_on_random_problems() {
    // smaller randomized sweep over shapes for the cache-based selectors
    forall_seeds(8, |seed| {
        let mut g = Gen::new(seed * 13 + 1);
        let n = g.size(4, 10);
        let m = g.size(5, 12);
        let lam = g.lambda(-1, 1);
        let x = g.matrix(n, m);
        let y = g.labels(m);
        let cfg = SelectionConfig {
            k: 3.min(n),
            lambda: lam,
            loss: Loss::Squared,
            ..Default::default()
        };
        check_session_equivalence(&GreedyRls, &x, &y, &cfg);
        check_session_equivalence(&LowRankLsSvm, &x, &y, &cfg);
        check_session_equivalence(&BackwardElimination, &x, &y, &cfg);
        check_session_equivalence(
            &NFoldGreedy { folds: 3, seed: 1 },
            &x,
            &y,
            &cfg,
        );
    });
}

// ---------------------------------------------------------------------------
// Thread-count equivalence: the deterministic parallel execution layer
// must produce bit-identical selected sets, criterion curves, and weights
// at threads ∈ {1, 2, 4}, for every selector — including warm-started
// sessions resumed under a different thread count than the recording run.
// ---------------------------------------------------------------------------

fn check_thread_equivalence<S: Selector + SessionSelector>(
    sel: &S,
    x: &greedy_rls::linalg::Matrix,
    y: &[f64],
    base: &SelectionConfig,
) {
    let name = sel.name();
    let serial = sel
        .select(x, y, &SelectionConfig { threads: 1, ..*base })
        .unwrap();
    for threads in [2usize, 4] {
        let par = sel
            .select(x, y, &SelectionConfig { threads, ..*base })
            .unwrap();
        assert_bit_identical(
            &serial,
            &par,
            &format!("{name}: threads={threads}"),
        );
    }
    // a warm start recorded serially and resumed on 4 threads must
    // continue the identical trajectory
    let replay: Vec<usize> = serial.rounds.iter().map(|r| r.feature).collect();
    if replay.len() > 1 {
        let cut = replay.len() / 2;
        let session = sel
            .begin_from(
                x,
                y,
                &SelectionConfig { threads: 4, ..*base },
                &replay[..cut],
            )
            .unwrap();
        let resumed = run_to_completion(session).unwrap();
        assert_bit_identical(
            &serial,
            &resumed,
            &format!("{name}: warm start across thread counts"),
        );
    }
}

#[test]
fn thread_counts_are_bit_identical_for_every_selector() {
    let ds = synthetic::two_gaussians(40, 13, 4, 1.5, 77);
    for loss in [Loss::Squared, Loss::ZeroOne] {
        let base = SelectionConfig {
            k: 4,
            lambda: 0.8,
            loss,
            ..Default::default()
        };
        check_thread_equivalence(&GreedyRls, &ds.x, &ds.y, &base);
        check_thread_equivalence(&Wrapper::shortcut(), &ds.x, &ds.y, &base);
        check_thread_equivalence(&LowRankLsSvm, &ds.x, &ds.y, &base);
        check_thread_equivalence(
            &RandomSelector { seed: 5 },
            &ds.x,
            &ds.y,
            &base,
        );
        check_thread_equivalence(&BackwardElimination, &ds.x, &ds.y, &base);
        check_thread_equivalence(
            &FloatingForward::default(),
            &ds.x,
            &ds.y,
            &base,
        );
        check_thread_equivalence(&Foba::default(), &ds.x, &ds.y, &base);
        check_thread_equivalence(
            &NFoldGreedy { folds: 5, seed: 2 },
            &ds.x,
            &ds.y,
            &base,
        );
        check_thread_equivalence(&GreedyRankRls, &ds.x, &ds.y, &base);
        check_thread_equivalence(
            &CenterSelector { kernel: Kernel::Rbf { gamma: 0.7 } },
            &ds.x,
            &ds.y,
            &base,
        );
    }
}

/// Property sweep over random shapes — active-list lengths that straddle
/// quad boundaries, holes from committed features, n smaller and larger
/// than the thread counts.
#[test]
fn thread_equivalence_holds_on_random_problems() {
    forall_seeds(10, |seed| {
        let mut g = Gen::new(seed * 11 + 7);
        let n = g.size(3, 15);
        let m = g.size(4, 12);
        let lam = g.lambda(-1, 1);
        let x = g.matrix(n, m);
        let y = g.labels(m);
        let base = SelectionConfig {
            k: 3.min(n),
            lambda: lam,
            loss: Loss::Squared,
            ..Default::default()
        };
        check_thread_equivalence(&GreedyRls, &x, &y, &base);
        check_thread_equivalence(&BackwardElimination, &x, &y, &base);
        check_thread_equivalence(
            &NFoldGreedy { folds: 3, seed: 1 },
            &x,
            &y,
            &base,
        );
    });
}

#[test]
fn selection_is_deterministic() {
    let ds = synthetic::two_gaussians(60, 20, 5, 1.0, 23);
    let cfg = SelectionConfig { k: 6, lambda: 1.0, loss: Loss::ZeroOne, ..Default::default() };
    let a = GreedyRls.select(&ds.x, &ds.y, &cfg).unwrap();
    let b = GreedyRls.select(&ds.x, &ds.y, &cfg).unwrap();
    assert_eq!(a.selected, b.selected);
    assert_eq!(a.weights, b.weights);
}

#[test]
fn criterion_trajectories_match_across_algorithms() {
    let mut g = Gen::new(404);
    let x = g.matrix(8, 10);
    let y = g.labels(10);
    let cfg = SelectionConfig { k: 4, lambda: 2.0, loss: Loss::ZeroOne, ..Default::default() };
    let r2 = LowRankLsSvm.select(&x, &y, &cfg).unwrap();
    let r3 = GreedyRls.select(&x, &y, &cfg).unwrap();
    let c2 = r2.criterion_curve();
    let c3 = r3.criterion_curve();
    assert_eq!(c2.len(), c3.len());
    for (a, b) in c2.iter().zip(&c3) {
        assert!((a - b).abs() < 1e-9, "{c2:?} vs {c3:?}");
    }
}
