//! Backend equivalence — the storage layer must be invisible in the
//! numbers (integration level).
//!
//! The out-of-core contract (ARCHITECTURE.md §Data backends): whether a
//! dataset lives in RAM, streams through a memory-mapped store, or is
//! served to selectors as a mapped read-only matrix, the selected sets,
//! per-round criteria, and final weights are **bit-identical** — at
//! every thread count, tile width, and window size. These tests drive
//! the full public surface: the two libsvm loaders, the mapped-matrix
//! `Dataset` path every selector consumes, the stored greedy engine,
//! and the cross-backend checkpoint fingerprint.

use greedy_rls::data::storage::{Backend, MatrixStore, StorageOptions};
use greedy_rls::data::{fingerprint, libsvm, synthetic, Dataset};
use greedy_rls::metrics::Loss;
use greedy_rls::select::{
    greedy::GreedyRls, run_to_completion, SelectionConfig, SelectionResult,
    Selector,
};

fn write_temp_libsvm(ds: &Dataset, tag: &str) -> std::path::PathBuf {
    use std::io::Write;
    let p = std::env::temp_dir().join(format!(
        "greedy-rls-beq-{tag}-{}.libsvm",
        std::process::id()
    ));
    let mut f = std::fs::File::create(&p).unwrap();
    f.write_all(libsvm::to_string(ds).as_bytes()).unwrap();
    p
}

fn mmap_opts() -> StorageOptions {
    StorageOptions::default()
        .backend(Backend::Mmap)
        .window_bytes(0) // clamps to the 1 MiB floor: many tiny windows
        .chunk_bytes(0) // clamps to the 4 KiB floor: many refills
}

fn assert_bit_identical(a: &SelectionResult, b: &SelectionResult, what: &str) {
    assert_eq!(a.selected, b.selected, "{what}: selected");
    assert_eq!(a.rounds.len(), b.rounds.len(), "{what}: round count");
    for (i, (ra, rb)) in a.rounds.iter().zip(&b.rounds).enumerate() {
        assert_eq!(ra.feature, rb.feature, "{what}: round {i} feature");
        assert_eq!(
            ra.criterion.to_bits(),
            rb.criterion.to_bits(),
            "{what}: round {i} criterion {} vs {}",
            ra.criterion,
            rb.criterion
        );
    }
    assert_eq!(a.weights.len(), b.weights.len(), "{what}: weight count");
    for (i, (wa, wb)) in a.weights.iter().zip(&b.weights).enumerate() {
        assert_eq!(
            wa.to_bits(),
            wb.to_bits(),
            "{what}: weight {i} {wa} vs {wb}"
        );
    }
}

// ---------------------------------------------------------------------------
// Loaders: the streaming out-of-core parser and the in-RAM parser must
// produce byte-identical matrices from the same file.
// ---------------------------------------------------------------------------

#[test]
fn streamed_loader_matches_inram_loader_bitwise() {
    let src = synthetic::two_gaussians(41, 13, 4, 1.2, 91);
    let path = write_temp_libsvm(&src, "loader");
    let ram = libsvm::parse_file(&path, None).unwrap();
    let mut all = vec![StorageOptions::default().chunk_bytes(0)];
    if cfg!(target_os = "linux") {
        all.push(mmap_opts());
    }
    for opts in all {
        let stored = libsvm::parse_file_stored(&path, None, &opts).unwrap();
        assert_eq!(stored.name, ram.name, "{:?}", opts.backend);
        assert_eq!(stored.y, ram.y, "{:?}", opts.backend);
        let got = stored.to_dataset().unwrap();
        for (a, b) in got.x.as_slice().iter().zip(ram.x.as_slice()) {
            assert_eq!(a.to_bits(), b.to_bits(), "{:?}", opts.backend);
        }
    }
    std::fs::remove_file(&path).unwrap();
}

// ---------------------------------------------------------------------------
// Mapped-matrix datasets: `load_file` on the mmap backend hands selectors
// a Dataset whose matrix is a read-only mapping of the scratch file. The
// whole selector roster must produce bit-identical results on it, at
// threads {1, 2, 4}.
// ---------------------------------------------------------------------------

#[cfg(target_os = "linux")]
fn check_backend_equivalence<S: Selector>(
    sel: &S,
    ram: &Dataset,
    mapped: &Dataset,
    base: &SelectionConfig,
) {
    let name = sel.name();
    for threads in [1usize, 2, 4] {
        let cfg = SelectionConfig { threads, ..*base };
        let a = sel.select(&ram.x, &ram.y, &cfg).unwrap();
        let b = sel.select(&mapped.x, &mapped.y, &cfg).unwrap();
        assert_bit_identical(
            &a,
            &b,
            &format!("{name}: ram vs mmap, threads={threads}"),
        );
    }
}

#[cfg(target_os = "linux")]
#[test]
fn every_selector_is_bit_identical_on_a_mapped_dataset() {
    use greedy_rls::rls::kernel::Kernel;
    use greedy_rls::select::{
        backward::BackwardElimination, centers::CenterSelector,
        floating::FloatingForward, foba::Foba, lowrank::LowRankLsSvm,
        nfold::NFoldGreedy, random::RandomSelector, rankrls::GreedyRankRls,
        wrapper::Wrapper,
    };

    let src = synthetic::two_gaussians(36, 11, 4, 1.5, 55);
    let path = write_temp_libsvm(&src, "roster");
    let ram = libsvm::parse_file(&path, None).unwrap();
    let mapped = libsvm::load_file(&path, None, &mmap_opts()).unwrap();
    for loss in [Loss::Squared, Loss::ZeroOne] {
        let base =
            SelectionConfig { k: 4, lambda: 0.8, loss, ..Default::default() };
        check_backend_equivalence(&GreedyRls, &ram, &mapped, &base);
        check_backend_equivalence(&Wrapper::shortcut(), &ram, &mapped, &base);
        check_backend_equivalence(&LowRankLsSvm, &ram, &mapped, &base);
        check_backend_equivalence(
            &RandomSelector { seed: 5 },
            &ram,
            &mapped,
            &base,
        );
        check_backend_equivalence(&BackwardElimination, &ram, &mapped, &base);
        check_backend_equivalence(
            &FloatingForward::default(),
            &ram,
            &mapped,
            &base,
        );
        check_backend_equivalence(&Foba::default(), &ram, &mapped, &base);
        check_backend_equivalence(
            &NFoldGreedy { folds: 4, seed: 2 },
            &ram,
            &mapped,
            &base,
        );
        check_backend_equivalence(&GreedyRankRls, &ram, &mapped, &base);
        check_backend_equivalence(
            &CenterSelector { kernel: Kernel::Rbf { gamma: 0.7 } },
            &ram,
            &mapped,
            &base,
        );
    }
    std::fs::remove_file(&path).unwrap();
}

// ---------------------------------------------------------------------------
// Stored greedy engine: the windowed out-of-core scan/commit engine vs
// the in-RAM engine, across thread counts, tile widths, and warm starts.
// ---------------------------------------------------------------------------

fn stored_result(
    src: &Dataset,
    cfg: &SelectionConfig,
    opts: &StorageOptions,
    warm: &[usize],
) -> SelectionResult {
    let x = MatrixStore::from_matrix(&src.x, opts).unwrap();
    let session = if warm.is_empty() {
        GreedyRls.begin_stored(x, src.y.clone(), cfg, opts).unwrap()
    } else {
        GreedyRls
            .begin_stored_from(x, src.y.clone(), cfg, opts, warm)
            .unwrap()
    };
    run_to_completion(session).unwrap()
}

#[test]
fn stored_engine_matches_inram_engine_across_knobs() {
    let src = synthetic::two_gaussians(44, 14, 5, 1.3, 29);
    for loss in [Loss::Squared, Loss::ZeroOne] {
        for threads in [1usize, 2, 4] {
            let cfg = SelectionConfig {
                k: 5,
                lambda: 0.7,
                loss,
                threads,
                ..Default::default()
            };
            let ram = GreedyRls.select(&src.x, &src.y, &cfg).unwrap();
            let mut variants = vec![
                StorageOptions::default(),
                StorageOptions::default().tile_cols(16),
            ];
            if cfg!(target_os = "linux") {
                variants.push(mmap_opts());
                variants.push(mmap_opts().tile_cols(8));
            }
            for opts in variants {
                let got = stored_result(&src, &cfg, &opts, &[]);
                assert_bit_identical(
                    &ram,
                    &got,
                    &format!(
                        "stored {:?} tile={} threads={threads}",
                        opts.backend, opts.tile_cols
                    ),
                );
            }
        }
    }
}

#[test]
fn stored_warm_start_continues_the_inram_trajectory() {
    let src = synthetic::two_gaussians(40, 12, 4, 1.4, 61);
    let cfg = SelectionConfig {
        k: 5,
        lambda: 1.1,
        loss: Loss::ZeroOne,
        ..Default::default()
    };
    let full = GreedyRls.select(&src.x, &src.y, &cfg).unwrap();
    let replay: Vec<usize> = full.rounds.iter().map(|r| r.feature).collect();
    let mut variants = vec![StorageOptions::default()];
    if cfg!(target_os = "linux") {
        variants.push(mmap_opts());
    }
    for opts in variants {
        for cut in [1usize, replay.len() / 2] {
            let got = stored_result(&src, &cfg, &opts, &replay[..cut]);
            assert_bit_identical(
                &full,
                &got,
                &format!("warm start {:?} at {cut}", opts.backend),
            );
        }
    }
}

#[test]
fn tiled_inram_selection_matches_untiled() {
    // `--tile-cols` on the default RAM path: the same engine, scanning in
    // LLC-sized column tiles, must reproduce the untiled run bit-for-bit.
    let src = synthetic::two_gaussians(52, 15, 5, 1.2, 83);
    for loss in [Loss::Squared, Loss::ZeroOne] {
        let base = SelectionConfig {
            k: 5,
            lambda: 0.9,
            loss,
            ..Default::default()
        };
        let untiled = GreedyRls.select(&src.x, &src.y, &base).unwrap();
        for tile_cols in [8usize, 16, 48] {
            for threads in [1usize, 3] {
                let cfg =
                    SelectionConfig { tile_cols, threads, ..base };
                let tiled = GreedyRls.select(&src.x, &src.y, &cfg).unwrap();
                assert_bit_identical(
                    &untiled,
                    &tiled,
                    &format!("tile={tile_cols} threads={threads}"),
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Cross-backend durability: standardization and the checkpoint data
// fingerprint must agree between the RAM and stored pipelines, so
// checkpoints written by one backend verify under the other.
// ---------------------------------------------------------------------------

#[test]
fn standardization_and_fingerprint_interchange_across_backends() {
    let mut ram = synthetic::two_gaussians(33, 9, 3, 1.6, 17);
    let mut variants = vec![StorageOptions::default()];
    if cfg!(target_os = "linux") {
        variants.push(mmap_opts());
    }
    let ram_stats = ram.standardize();
    let ram_fp = fingerprint::fingerprint_xy(&ram.x, &ram.y);
    for opts in variants {
        let mut stored =
            synthetic::two_gaussians_stored(33, 9, 3, 1.6, 17, &opts)
                .unwrap();
        let stats = stored.standardize().unwrap();
        assert_eq!(stats, ram_stats, "{:?}", opts.backend);
        assert_eq!(
            stored.fingerprint().unwrap(),
            ram_fp,
            "{:?}",
            opts.backend
        );
        let got = stored.to_dataset().unwrap();
        for (a, b) in got.x.as_slice().iter().zip(ram.x.as_slice()) {
            assert_eq!(a.to_bits(), b.to_bits(), "{:?}", opts.backend);
        }
    }
}

// ---------------------------------------------------------------------------
// Kernel tier × backends: both backends dispatch the same active kernel
// (see rust/tests/kernel_equivalence.rs for the forced-scalar pin), and
// the precision knob must behave identically across them — f64 results
// interchange, f32c is rejected by the stored engine with the same
// uniform fence everywhere.
// ---------------------------------------------------------------------------

#[test]
fn precision_knob_is_uniform_across_backends() {
    use greedy_rls::select::Precision;
    let src = synthetic::two_gaussians(36, 10, 3, 1.2, 47);
    let f64_cfg = SelectionConfig {
        k: 3,
        lambda: 1.0,
        loss: Loss::ZeroOne,
        ..Default::default()
    };
    // the f64 default: ram and stored agree bitwise (kernel dispatch is
    // per-build, identical on both backends)
    let ram = GreedyRls.select(&src.x, &src.y, &f64_cfg).unwrap();
    let stored =
        stored_result(&src, &f64_cfg, &StorageOptions::default(), &[]);
    assert_bit_identical(&ram, &stored, "f64 ram vs stored");
    // f32c: accepted in RAM, rejected by the stored engine on every
    // backend variant (its cache streams f64 windows)
    let f32_cfg =
        SelectionConfig { precision: Precision::F32c, ..f64_cfg };
    assert!(GreedyRls.select(&src.x, &src.y, &f32_cfg).is_ok());
    let mut variants = vec![StorageOptions::default()];
    if cfg!(target_os = "linux") {
        variants.push(mmap_opts());
    }
    for opts in variants {
        let x = MatrixStore::from_matrix(&src.x, &opts).unwrap();
        let err = GreedyRls
            .begin_stored(x, src.y.clone(), &f32_cfg, &opts)
            .unwrap_err();
        assert!(err.to_string().contains("f32c"), "{:?}: {err}", opts.backend);
    }
}
