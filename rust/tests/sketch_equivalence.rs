//! Sketched-preselection equivalence (integration level).
//!
//! The filter-then-exact contract (ARCHITECTURE.md §Sketched
//! preselection): the leverage-score filter decides only *who may
//! compete* — everything downstream is the exact greedy engine. So a
//! filtered run must be bit-identical across thread counts, losses,
//! and data backends; an identity filter (`p >= n`) must reproduce the
//! unfiltered trajectory bitwise down to the checkpoint bytes; and the
//! session machinery (warm starts, kill/resume) must compose with the
//! filter without ever letting a non-survivor in. Plus the group-drop
//! FoBa variant and the config-fingerprint marker semantics.

use std::path::PathBuf;

use greedy_rls::data::storage::{MatrixStore, StorageOptions};
use greedy_rls::data::synthetic;
use greedy_rls::metrics::Loss;
use greedy_rls::select::checkpoint::{
    self, drive_checkpointed, resume_from_path, AutosavePolicy, Autosaver,
};
use greedy_rls::select::sketch::{leverage_scores, top_p};
use greedy_rls::select::{
    foba::{DroppingFoba, Foba},
    greedy::GreedyRls,
    run_to_completion, KernelKind, NoopObserver, PreselectConfig,
    SelectionConfig, SelectionResult, Selector, SessionSelector,
    SketchedGreedy,
};

fn scratch_dir(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("greedy_rls_sketch_it_{tag}"));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

fn assert_bit_identical(a: &SelectionResult, b: &SelectionResult, what: &str) {
    assert_eq!(a.selected, b.selected, "{what}: selected");
    assert_eq!(a.rounds.len(), b.rounds.len(), "{what}: round count");
    for (i, (ra, rb)) in a.rounds.iter().zip(&b.rounds).enumerate() {
        assert_eq!(ra.feature, rb.feature, "{what}: round {i} feature");
        assert_eq!(
            ra.criterion.to_bits(),
            rb.criterion.to_bits(),
            "{what}: round {i} criterion {} vs {}",
            ra.criterion,
            rb.criterion
        );
    }
    for (i, (wa, wb)) in a.weights.iter().zip(&b.weights).enumerate() {
        assert_eq!(wa.to_bits(), wb.to_bits(), "{what}: weight {i}");
    }
}

fn ps(p: usize, d: usize, seed: u64) -> PreselectConfig {
    PreselectConfig { p, sketch_dim: d, seed }
}

// ---------------------------------------------------------------------------
// Determinism: one filtered trajectory per (data, config), regardless of
// thread count, sketch usage, or data backend.
// ---------------------------------------------------------------------------

#[test]
fn filtered_selection_is_deterministic_across_threads_and_backends() {
    let src = synthetic::two_gaussians(44, 14, 5, 1.3, 29);
    for loss in [Loss::Squared, Loss::ZeroOne] {
        // both score paths: exact (d = 0) and a genuinely sketched d
        for d in [0usize, 4] {
            let base = SelectionConfig {
                k: 4,
                lambda: 0.7,
                loss,
                preselect: Some(ps(8, d, 7)),
                ..Default::default()
            };
            let reference =
                SketchedGreedy.select(&src.x, &src.y, &base).unwrap();
            assert_eq!(reference.selected.len(), 4, "loss {loss:?} d={d}");

            // survivor containment: the exact engine may only ever pick
            // from the filter's top-p set (recomputed here through the
            // public scoring surface)
            let scores = leverage_scores(
                &src.x,
                base.lambda,
                &ps(8, d, 7),
                1,
                KernelKind::active(),
            )
            .unwrap();
            let survivors = top_p(&scores, 8);
            for f in &reference.selected {
                assert!(
                    survivors.contains(f),
                    "selected {f} escaped the survivor set {survivors:?}"
                );
            }

            for threads in [2usize, 4] {
                let cfg = SelectionConfig { threads, ..base };
                let got =
                    SketchedGreedy.select(&src.x, &src.y, &cfg).unwrap();
                assert_bit_identical(
                    &reference,
                    &got,
                    &format!("loss {loss:?} d={d} threads={threads}"),
                );
            }

            // stored backend(s): the greedy core applies the same filter
            // from cfg.preselect, staging rows through read_row_into
            let mut variants = vec![
                StorageOptions::default(),
                StorageOptions::default().tile_cols(8),
            ];
            if cfg!(target_os = "linux") {
                use greedy_rls::data::storage::Backend;
                variants.push(
                    StorageOptions::default()
                        .backend(Backend::Mmap)
                        .window_bytes(0)
                        .chunk_bytes(0),
                );
            }
            for opts in variants {
                let x = MatrixStore::from_matrix(&src.x, &opts).unwrap();
                let session = GreedyRls
                    .begin_stored(x, src.y.clone(), &base, &opts)
                    .unwrap();
                let got = run_to_completion(session).unwrap();
                assert_bit_identical(
                    &reference,
                    &got,
                    &format!(
                        "loss {loss:?} d={d} stored {:?}",
                        opts.backend
                    ),
                );
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Identity filter: p >= n is plain greedy, bitwise — checkpoint bytes
// included (the fingerprint marker normalizes away, so the two runs'
// checkpoint files are byte-for-byte interchangeable).
// ---------------------------------------------------------------------------

#[test]
fn identity_filter_reproduces_exact_greedy_bitwise() {
    let ds = synthetic::two_gaussians(40, 12, 4, 1.5, 51);
    let n = ds.x.rows();
    for loss in [Loss::Squared, Loss::ZeroOne] {
        let plain = SelectionConfig {
            k: 5,
            lambda: 0.9,
            loss,
            ..Default::default()
        };
        let exact = GreedyRls.select(&ds.x, &ds.y, &plain).unwrap();
        // p = n and p > n, with and without a sketch dim: the identity
        // check fires before any scoring, so no RNG is ever consumed
        for (p, d) in [(n, 0), (n, 3), (n + 7, 0)] {
            for threads in [1usize, 2, 4] {
                let cfg = SelectionConfig {
                    threads,
                    preselect: Some(ps(p, d, 999)),
                    ..plain
                };
                let got =
                    SketchedGreedy.select(&ds.x, &ds.y, &cfg).unwrap();
                assert_bit_identical(
                    &exact,
                    &got,
                    &format!("identity p={p} d={d} threads={threads}"),
                );
            }
        }
    }
}

#[test]
fn identity_filter_checkpoints_are_byte_identical_to_plain_greedy() {
    let ds = synthetic::two_gaussians(38, 11, 4, 1.4, 77);
    let n = ds.x.rows();
    let plain = SelectionConfig {
        k: 4,
        lambda: 1.1,
        loss: Loss::ZeroOne,
        ..Default::default()
    };
    let filtered =
        SelectionConfig { preselect: Some(ps(n, 0, 123)), ..plain };

    let record = |cfg: &SelectionConfig, tag: &str| -> PathBuf {
        let dir = scratch_dir(tag);
        let fp = checkpoint::fingerprint(&ds.x, &ds.y, cfg);
        let mut session = GreedyRls.begin(&ds.x, &ds.y, cfg).unwrap();
        let mut saver =
            Autosaver::new(&dir, AutosavePolicy::default(), fp).unwrap();
        drive_checkpointed(session.as_mut(), &mut NoopObserver, &mut saver)
            .unwrap();
        session.finish().unwrap();
        dir
    };
    let plain_dir = record(&plain, "plain");
    let filtered_dir = record(&filtered, "identity");
    for round in 1..=plain.k {
        let a =
            std::fs::read(checkpoint::checkpoint_path(&plain_dir, round))
                .unwrap();
        let b = std::fs::read(checkpoint::checkpoint_path(
            &filtered_dir,
            round,
        ))
        .unwrap();
        assert_eq!(a, b, "round {round}: checkpoint bytes diverged");
    }
    // and each resumes the other's run (same fingerprint both ways)
    let cut = checkpoint::checkpoint_path(&plain_dir, 2);
    let (s, _) =
        resume_from_path(&SketchedGreedy, &ds.x, &ds.y, &filtered, &cut)
            .unwrap();
    let crossed = run_to_completion(s).unwrap();
    let exact = GreedyRls.select(&ds.x, &ds.y, &plain).unwrap();
    assert_bit_identical(&exact, &crossed, "cross-resume");
    let _ = std::fs::remove_dir_all(&plain_dir);
    let _ = std::fs::remove_dir_all(&filtered_dir);
}

// ---------------------------------------------------------------------------
// Session machinery on a *real* filter: warm starts replay inside the
// survivor set, kill/resume lands on the identical trajectory at any
// thread count.
// ---------------------------------------------------------------------------

#[test]
fn filtered_runs_survive_warm_start_and_kill_resume() {
    let ds = synthetic::two_gaussians(42, 14, 5, 1.2, 63);
    let cfg = SelectionConfig {
        k: 4,
        lambda: 0.8,
        loss: Loss::ZeroOne,
        preselect: Some(ps(8, 3, 11)),
        ..Default::default()
    };
    let full = SketchedGreedy.select(&ds.x, &ds.y, &cfg).unwrap();
    let replay: Vec<usize> = full.rounds.iter().map(|r| r.feature).collect();

    // warm start from every prefix: forced rounds stay inside the
    // survivor set (they were selected from it), and the continuation
    // is bit-identical
    for cut in 1..replay.len() {
        let s = SketchedGreedy
            .begin_from(&ds.x, &ds.y, &cfg, &replay[..cut])
            .unwrap();
        let got = run_to_completion(s).unwrap();
        assert_bit_identical(&full, &got, &format!("warm start at {cut}"));
    }

    // kill/resume: record with autosave-every-round, resume from each
    // cut at several thread counts
    let dir = scratch_dir("kill_resume");
    let fp = checkpoint::fingerprint(&ds.x, &ds.y, &cfg);
    let mut session = SketchedGreedy.begin(&ds.x, &ds.y, &cfg).unwrap();
    let mut saver =
        Autosaver::new(&dir, AutosavePolicy::default(), fp).unwrap();
    drive_checkpointed(session.as_mut(), &mut NoopObserver, &mut saver)
        .unwrap();
    assert_bit_identical(
        &full,
        &session.finish().unwrap(),
        "recorded run",
    );
    for cut in [1usize, 2, replay.len()] {
        let path = checkpoint::checkpoint_path(&dir, cut);
        assert!(path.exists(), "missing checkpoint at round {cut}");
        for threads in [1usize, 2, 4] {
            let tcfg = SelectionConfig { threads, ..cfg };
            let (s, ckpt) =
                resume_from_path(&SketchedGreedy, &ds.x, &ds.y, &tcfg, &path)
                    .unwrap();
            assert_eq!(ckpt.rounds.len(), cut);
            let resumed = run_to_completion(s).unwrap();
            assert_bit_identical(
                &full,
                &resumed,
                &format!("killed at {cut}, resumed on {threads}t"),
            );
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn k_larger_than_p_is_rejected_up_front() {
    let ds = synthetic::two_gaussians(30, 10, 3, 1.5, 5);
    let cfg = SelectionConfig {
        k: 6,
        preselect: Some(ps(4, 0, 1)),
        ..Default::default()
    };
    let err = SketchedGreedy.select(&ds.x, &ds.y, &cfg).unwrap_err();
    assert!(err.to_string().contains("survivor"), "{err}");
}

// ---------------------------------------------------------------------------
// Group-drop FoBa: on well-separated data no deletion is ever
// profitable, so the group-drop backward pass must agree with the
// one-at-a-time pass round for round.
// ---------------------------------------------------------------------------

#[test]
fn dropping_foba_matches_foba_on_well_separated_data() {
    let ds = synthetic::two_gaussians(48, 12, 4, 2.5, 33);
    for loss in [Loss::Squared, Loss::ZeroOne] {
        let cfg = SelectionConfig {
            k: 3,
            lambda: 1.0,
            loss,
            ..Default::default()
        };
        let a = Foba::default().select(&ds.x, &ds.y, &cfg).unwrap();
        let b =
            DroppingFoba::default().select(&ds.x, &ds.y, &cfg).unwrap();
        assert_bit_identical(&a, &b, &format!("loss {loss:?}"));
    }
}

// ---------------------------------------------------------------------------
// Config fingerprints: the preselect marker participates exactly when
// the filter can change the trajectory, and legacy (unfiltered) hashes
// are untouched by the new field.
// ---------------------------------------------------------------------------

#[test]
fn preselect_marker_participates_in_config_hashes_when_it_matters() {
    let base = SelectionConfig {
        k: 4,
        lambda: 0.5,
        loss: Loss::ZeroOne,
        ..Default::default()
    };
    let legacy = checkpoint::config_hash(&base);
    // the delegating form agrees with the legacy entry point
    assert_eq!(legacy, checkpoint::config_hash_for(&base, None));
    // a filter that can bite changes the hash, and every field of the
    // marker participates
    let f = |p, d, seed| SelectionConfig {
        preselect: Some(ps(p, d, seed)),
        ..base
    };
    let h = |cfg: &SelectionConfig| checkpoint::config_hash_for(cfg, Some(20));
    assert_ne!(h(&f(8, 0, 1)), legacy, "p < n must leave a marker");
    assert_ne!(h(&f(9, 0, 1)), h(&f(8, 0, 1)), "p participates");
    assert_ne!(h(&f(8, 3, 1)), h(&f(8, 0, 1)), "sketch_dim participates");
    assert_ne!(h(&f(8, 3, 2)), h(&f(8, 3, 1)), "seed participates");
    // identity filters normalize away: byte-compatible with legacy
    assert_eq!(h(&f(20, 0, 1)), legacy, "p = n is the identity");
    assert_eq!(h(&f(25, 3, 9)), legacy, "p > n is the identity");
    // without n, only a missing filter matches legacy (conservative)
    assert_ne!(
        checkpoint::config_hash_for(&f(20, 0, 1), None),
        legacy,
        "n unknown: the marker must stay"
    );
}
