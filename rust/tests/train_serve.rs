//! Integration tests for the streaming serve pipeline (`coordinator::
//! stream`): end-to-end train-serve vs `serve --follow` parity,
//! kill/resume convergence, and the concurrent-swap stress test run
//! through **both** follower paths (checkpoint trail and in-process
//! bus) against one shared consistency assertion.

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Duration;

use greedy_rls::coordinator::serve::{
    serve_hotswap, CheckpointFollower, HotSwapServer, ModelSource,
};
use greedy_rls::coordinator::stream::{
    self, BusWait, ModelBus, TrainServeOptions,
};
use greedy_rls::data::synthetic::two_gaussians;
use greedy_rls::rls::Predictor;
use greedy_rls::select::checkpoint::{
    self, fingerprint, AutosavePolicy, Autosaver, Checkpoint,
};
use greedy_rls::select::greedy::GreedyRls;
use greedy_rls::select::{
    NoopObserver, SelectionConfig, SessionSelector, StopReason,
};

fn temp_dir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Acceptance: selection to k rounds publishes ≥ k versions over the
/// bus, and the final pass answers match `serve --follow` over the same
/// trail bit-for-bit.
#[test]
fn train_serve_publishes_every_round_and_matches_follow() {
    let dir = temp_dir("greedy_rls_ts_parity");
    let ds = two_gaussians(150, 40, 8, 1.5, 7);
    let k = 6;
    let cfg = SelectionConfig::builder().k(k).lambda(1.0).build();
    let fp = fingerprint(&ds.x, &ds.y, &cfg);

    let mut saver =
        Autosaver::new(&dir, AutosavePolicy::default(), fp).unwrap();
    let session = GreedyRls.begin(&ds.x, &ds.y, &cfg).unwrap();
    let opts = TrainServeOptions { workers: 3, batch: 32, queue_depth: 0 };
    let report = stream::train_serve(
        session,
        &mut NoopObserver,
        Some(&mut saver),
        &ds.x,
        &opts,
    )
    .unwrap();

    assert_eq!(report.stop, StopReason::TargetReached);
    assert_eq!(report.result.selected.len(), k);
    assert!(
        report.published >= k as u64,
        "k rounds must publish ≥ k versions, got {}",
        report.published
    );

    // serve --follow over the finished trail: every batch is answered by
    // the final model, exactly like train-serve's final pass
    let followed = stream::follow_final_pass(&dir, &ds.x, 32).unwrap();
    assert_eq!(report.final_preds.len(), followed.len());
    for (i, (a, b)) in
        report.final_preds.iter().zip(&followed).enumerate()
    {
        assert_eq!(a.to_bits(), b.to_bits(), "prediction {i} differs");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// Acceptance: a run killed mid-flight (emulated by truncating the
/// checkpoint trail — CI's gauntlet does the real SIGKILL) and resumed
/// with the same config converges to the identical final model.
#[test]
fn train_serve_resume_converges_to_identical_model() {
    let dir = temp_dir("greedy_rls_ts_resume_conv");
    let ds = two_gaussians(120, 30, 6, 1.5, 11);
    let cfg = SelectionConfig::builder().k(6).lambda(1.0).build();
    let fp = fingerprint(&ds.x, &ds.y, &cfg);

    // uninterrupted reference (plain select — serving must not perturb)
    let reference = greedy_rls::select::run_to_completion(
        GreedyRls.begin(&ds.x, &ds.y, &cfg).unwrap(),
    )
    .unwrap();

    let mut saver =
        Autosaver::new(&dir, AutosavePolicy::default(), fp).unwrap();
    let session = GreedyRls.begin(&ds.x, &ds.y, &cfg).unwrap();
    let opts = TrainServeOptions { workers: 2, batch: 16, queue_depth: 0 };
    let first = stream::train_serve(
        session,
        &mut NoopObserver,
        Some(&mut saver),
        &ds.x,
        &opts,
    )
    .unwrap();
    assert_eq!(first.result.selected, reference.selected);

    // "kill" after round 2
    for rounds in 3..=6 {
        std::fs::remove_file(checkpoint::checkpoint_path(&dir, rounds))
            .unwrap();
    }
    let latest = checkpoint::latest_in_dir(&dir).unwrap().unwrap();
    let (resumed, ckpt) =
        checkpoint::resume_from_path(&GreedyRls, &ds.x, &ds.y, &cfg, &latest)
            .unwrap();
    assert_eq!(ckpt.rounds.len(), 2);
    let mut saver2 =
        Autosaver::new(&dir, AutosavePolicy::default(), fp).unwrap();
    let second = stream::train_serve(
        resumed,
        &mut NoopObserver,
        Some(&mut saver2),
        &ds.x,
        &opts,
    )
    .unwrap();

    assert_eq!(second.result.selected, reference.selected);
    assert_eq!(second.result.weights, reference.weights);
    for (a, b) in second.result.rounds.iter().zip(&reference.rounds) {
        assert_eq!(a.criterion.to_bits(), b.criterion.to_bits());
    }
    // the final served model equals the reference predictor bit-for-bit
    let direct = reference.predictor().predict_matrix(&ds.x);
    for (a, b) in second.final_preds.iter().zip(&direct) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// `serve_hotswap` is source-agnostic: the same serving loop runs over a
/// `BusFollower` and produces the final model's predictions once the
/// publisher is done.
#[test]
fn serve_hotswap_runs_over_the_bus_source() {
    let ds = two_gaussians(90, 20, 5, 1.5, 13);
    let cfg = SelectionConfig::builder().k(4).lambda(1.0).build();
    let result = greedy_rls::select::run_to_completion(
        GreedyRls.begin(&ds.x, &ds.y, &cfg).unwrap(),
    )
    .unwrap();

    let bus = ModelBus::new();
    // publish the whole trajectory up front, then close: with the trail
    // complete, every batch is answered by the final model
    for r in 1..=result.selected.len() {
        bus.publish(
            Predictor {
                selected: result.selected[..r].to_vec(),
                weights: result.weights[..r].to_vec(), // placeholder prefix
            },
            r,
        );
    }
    bus.publish(result.predictor(), result.selected.len());
    bus.close();

    let mut follower = bus.follower();
    let first = follower.wait_for_model(Duration::from_secs(1)).unwrap();
    let server = HotSwapServer::new(first.predictor.clone());
    let (preds, stats) =
        serve_hotswap(&server, &mut follower, &ds.x, 16, 2, None).unwrap();
    let direct = result.predictor().predict_matrix(&ds.x);
    for (a, b) in preds.iter().zip(&direct) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    assert_eq!(stats.final_rounds, result.selected.len());
    assert_eq!(stats.serve.requests, 2 * ds.x.cols());
}

// ---------------------------------------------------------------------------
// Concurrent-swap stress, shared by both follower paths
// ---------------------------------------------------------------------------

/// Models used by the stress tests encode their version in the weight:
/// `selected = [0]`, `weight = version`. Over an all-ones feature row,
/// every prediction then equals the serving model's version — so a batch
/// whose predictions are not all identical saw a torn swap.
fn stress_predictor(version: usize) -> Predictor {
    Predictor { selected: vec![0], weights: vec![version as f64] }
}

/// Readers hammer `server.predict_batch` until `stop` flips, asserting
/// every batch is internally consistent (single version) and that
/// observed versions are monotone per reader. Returns the number of
/// distinct model generations observed across readers.
fn assert_consistent_under_swaps(
    server: &HotSwapServer,
    x: &greedy_rls::linalg::Matrix,
    stop: &AtomicBool,
    readers: usize,
) -> usize {
    let seen: Vec<_> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..readers)
            .map(|_| {
                scope.spawn(move || {
                    let mut seen = std::collections::BTreeSet::new();
                    let mut last_version = 0u64;
                    let mut last_weight = -1.0f64;
                    while !stop.load(Ordering::Acquire) {
                        let (preds, version) = server.predict_batch(x);
                        let first = preds[0];
                        for (j, &p) in preds.iter().enumerate() {
                            assert_eq!(
                                p.to_bits(),
                                first.to_bits(),
                                "batch torn at column {j}: {p} vs {first} \
                                 (version {version})"
                            );
                        }
                        assert!(
                            version >= last_version,
                            "server version went backwards"
                        );
                        // model generations must advance with versions:
                        // a *newer* version never serves an older model
                        if version > last_version {
                            assert!(
                                first >= last_weight,
                                "version {version} served generation \
                                 {first} after {last_weight}"
                            );
                            last_weight = first;
                        }
                        last_version = version;
                        seen.insert(first.to_bits());
                    }
                    seen
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let mut all = std::collections::BTreeSet::new();
    for s in seen {
        all.extend(s);
    }
    all.len()
}

/// An all-ones single-feature probe matrix: prediction == model weight.
fn ones_matrix(cols: usize) -> greedy_rls::linalg::Matrix {
    greedy_rls::linalg::Matrix::from_vec(1, cols, vec![1.0; cols])
}

#[test]
fn hotswap_stress_bus_follower_path() {
    let x = ones_matrix(256);
    let server = HotSwapServer::new(stress_predictor(0));
    let bus = ModelBus::new();
    let stop = AtomicBool::new(false);
    let generations = std::thread::scope(|scope| {
        // publisher: a new model generation every ~1ms
        let bus_ref = &bus;
        scope.spawn(move || {
            for gen in 1..=60usize {
                bus_ref.publish(stress_predictor(gen), gen);
                std::thread::sleep(Duration::from_millis(1));
            }
            bus_ref.close();
        });
        // swapper: apply bus versions to the server as they land
        let server_ref = &server;
        let stop_ref = &stop;
        let mut follower = bus.follower();
        scope.spawn(move || {
            loop {
                match follower.wait_newer(Duration::from_millis(50)) {
                    BusWait::Newer(v) => {
                        server_ref.swap(v.predictor.clone(), v.rounds);
                    }
                    BusWait::Closed => break,
                    BusWait::TimedOut => {}
                }
            }
            stop_ref.store(true, Ordering::Release);
        });
        assert_consistent_under_swaps(&server, &x, &stop, 3)
    });
    assert!(
        generations >= 2,
        "readers should observe several generations, saw {generations}"
    );
    assert_eq!(bus.published(), 60);
}

#[test]
fn hotswap_stress_checkpoint_follower_path() {
    let dir = temp_dir("greedy_rls_ts_stress_ckpt");
    let x = ones_matrix(256);
    let server = HotSwapServer::new(stress_predictor(0));
    let stop = AtomicBool::new(false);
    let writer_done = AtomicBool::new(false);

    let write_ckpt = |generation: usize| {
        let ckpt = Checkpoint {
            fingerprint: checkpoint::Fingerprint { config: 1, data: 2 },
            elapsed: Duration::ZERO,
            stop_reason: None,
            rounds: (0..generation)
                .map(|i| greedy_rls::select::Round {
                    feature: i,
                    criterion: 1.0,
                })
                .collect(),
            selected: vec![0],
            weights: vec![generation as f64],
        };
        ckpt.save_atomic(&checkpoint::checkpoint_path(&dir, generation))
            .unwrap();
    };

    let generations = std::thread::scope(|scope| {
        // writer: a new checkpoint generation every ~2ms (atomic renames,
        // exactly what a live checkpointing session produces)
        let writer_done_ref = &writer_done;
        let write_ref = &write_ckpt;
        scope.spawn(move || {
            for generation in 1..=40usize {
                write_ref(generation);
                std::thread::sleep(Duration::from_millis(2));
            }
            writer_done_ref.store(true, Ordering::Release);
        });
        // follower: poll the trail and swap newer models in
        let server_ref = &server;
        let stop_ref = &stop;
        let done_ref = &writer_done;
        let dir_ref = dir.clone();
        scope.spawn(move || {
            let mut follower = CheckpointFollower::new(&dir_ref);
            loop {
                let finished = done_ref.load(Ordering::Acquire);
                if let Some(update) = follower.poll_model().unwrap() {
                    server_ref.swap(update.predictor, update.rounds);
                } else if finished {
                    break;
                }
                std::thread::sleep(Duration::from_millis(1));
            }
            stop_ref.store(true, Ordering::Release);
        });
        assert_consistent_under_swaps(&server, &x, &stop, 3)
    });
    assert!(
        generations >= 2,
        "readers should observe several generations, saw {generations}"
    );
    // the trail's last generation is the one left serving
    assert_eq!(server.snapshot().predictor.weights, vec![40.0]);
    let _ = std::fs::remove_dir_all(&dir);
}
