//! Fault-injection suite for the multi-process serving fabric
//! (`coordinator::fabric`): wire-codec round-trip/rejection properties,
//! seeded fault-schedule replay, follower integrity under a fault storm
//! (never a torn model, never a version regression), publisher-restart
//! reconnects, checkpoint-trail degradation, and admission control on
//! the `serve --listen` front. The CI fleet gauntlet covers the same
//! invariants across real processes with a SIGKILL.

use std::path::{Path, PathBuf};
use std::sync::atomic::AtomicBool;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use greedy_rls::coordinator::fabric::fault::{
    FaultCounters, FaultPlan, FaultyProxy, FaultyStream,
};
use greedy_rls::coordinator::fabric::follow::SocketFollower;
use greedy_rls::coordinator::fabric::listen::{
    run_load, ListenOptions, ListenServer, LoadOptions,
};
use greedy_rls::coordinator::fabric::net::{Addr, Conn};
use greedy_rls::coordinator::fabric::publish::SocketPublisher;
use greedy_rls::coordinator::fabric::wire::{
    self, Frame, WireModel, FORMAT_VERSION, MAX_PAYLOAD,
};
use greedy_rls::coordinator::fabric::FabricOptions;
use greedy_rls::coordinator::serve::{HotSwapServer, ModelSource};
use greedy_rls::coordinator::stream::ModelBus;
use greedy_rls::linalg::Matrix;
use greedy_rls::proptest::{forall_seeds, Gen};
use greedy_rls::rls::Predictor;
use greedy_rls::select::checkpoint::{self, Checkpoint, Fingerprint};
use greedy_rls::select::Round;

// ---------------------------------------------------------------------------
// helpers

/// Unique unix-socket address per test (paths must stay short).
fn unix_addr(name: &str) -> Addr {
    let path = std::env::temp_dir()
        .join(format!("grls-fab-{}-{name}.sock", std::process::id()));
    let _ = std::fs::remove_file(&path);
    Addr::parse(&format!("unix:{}", path.display())).unwrap()
}

/// Tight timeouts so failure paths resolve in milliseconds, not the
/// production defaults.
fn fast_fabric() -> FabricOptions {
    FabricOptions {
        heartbeat: Duration::from_millis(50),
        read_timeout: Duration::from_millis(150),
        backoff_base: Duration::from_millis(10),
        backoff_cap: Duration::from_millis(100),
        ..FabricOptions::default()
    }
}

/// Version-tagged model: over an all-ones probe every prediction equals
/// the generation, and `weights[0] != rounds` proves a torn install.
fn versioned(generation: usize) -> Predictor {
    Predictor { selected: vec![0], weights: vec![generation as f64] }
}

/// Poll `f` until it returns true or `timeout` elapses.
fn wait_until<F: FnMut() -> bool>(what: &str, timeout: Duration, mut f: F) {
    let t0 = std::time::Instant::now();
    while t0.elapsed() < timeout {
        if f() {
            return;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    panic!("timed out after {timeout:?} waiting for {what}");
}

/// Drain every pending follower update, asserting each one is intact
/// (weights consistent with its version tag, expected data hash) and
/// strictly newer than the last — the "never torn, never regress"
/// invariant. Versions land in `seen`.
fn drain_checked(
    follower: &mut SocketFollower,
    seen: &mut Vec<usize>,
    expect_hash: Option<u64>,
) {
    while let Some(u) = follower.poll_model().unwrap() {
        assert_eq!(u.predictor.selected, vec![0], "torn model");
        assert_eq!(
            u.predictor.weights.len(),
            1,
            "torn model at rounds {}",
            u.rounds
        );
        assert_eq!(
            u.predictor.weights[0].to_bits(),
            (u.rounds as f64).to_bits(),
            "model/version mismatch at rounds {}: {:?}",
            u.rounds,
            u.predictor.weights
        );
        assert_eq!(u.data_hash, expect_hash);
        if let Some(&last) = seen.last() {
            assert!(u.rounds > last, "version regressed: {} after {last}", u.rounds);
        }
        seen.push(u.rounds);
    }
}

fn client(addr: &Addr) -> Conn {
    let conn = Conn::connect(addr, Duration::from_secs(1)).unwrap();
    conn.set_timeouts(
        Some(Duration::from_secs(5)),
        Some(Duration::from_secs(1)),
    )
    .unwrap();
    conn
}

/// Random frame with adversarial f64 bit patterns (raw u64 reinterpret
/// covers NaNs, infinities, -0.0, subnormals).
fn random_model_frame(g: &mut Gen) -> Frame {
    let k = g.size(1, 12);
    let selected: Vec<usize> =
        (0..k).map(|_| g.rng.below(1 << 20)).collect();
    let weights: Vec<f64> =
        (0..k).map(|_| f64::from_bits(g.rng.next_u64())).collect();
    Frame::Model(WireModel {
        rounds: g.size(1, 10_000),
        data_hash: (g.rng.below(2) == 0).then(|| g.rng.next_u64()),
        predictor: Predictor { selected, weights },
    })
}

// ---------------------------------------------------------------------------
// wire codec properties

#[test]
fn wire_roundtrip_is_bit_exact_for_random_models() {
    forall_seeds(32, |seed| {
        let mut g = Gen::new(seed + 500);
        let frame = random_model_frame(&mut g);
        let bytes = frame.encode();
        let back = Frame::decode(&bytes).unwrap();
        assert_eq!(back.encode(), bytes, "re-encode differs");
    });
}

#[test]
fn wire_rejects_truncated_flipped_wrong_version_and_oversized() {
    forall_seeds(48, |seed| {
        let mut g = Gen::new(seed + 900);
        let bytes = random_model_frame(&mut g).encode();

        // truncation at a random cut
        let cut = g.rng.below(bytes.len());
        assert!(
            Frame::decode(&bytes[..cut]).is_err(),
            "decoded a frame cut at {cut}"
        );

        // a single random bit flip (checksum covers every byte)
        let mut flipped = bytes.clone();
        let at = g.rng.below(flipped.len());
        flipped[at] ^= 1 << g.rng.below(8);
        assert!(
            Frame::decode(&flipped).is_err(),
            "bit flip at byte {at} went undetected"
        );

        // a random unsupported version
        let mut versioned = bytes.clone();
        let v = FORMAT_VERSION + 1 + g.rng.below(1000) as u32;
        versioned[4..8].copy_from_slice(&v.to_le_bytes());
        let err = Frame::decode(&versioned).unwrap_err();
        assert!(
            err.to_string().contains("unsupported wire format"),
            "version {v}: {err}"
        );

        // a length prefix past the payload cap is refused pre-allocation
        let mut oversized = bytes.clone();
        let plen = MAX_PAYLOAD as u32 + 1 + g.rng.below(1 << 20) as u32;
        oversized[9..13].copy_from_slice(&plen.to_le_bytes());
        let err = Frame::decode(&oversized).unwrap_err();
        assert!(err.to_string().contains("exceeds"), "plen {plen}: {err}");
    });
}

// ---------------------------------------------------------------------------
// fault injection primitives

/// A `Write` sink whose bytes outlive the `FaultyStream` wrapping it.
#[derive(Clone, Default)]
struct SharedSink(Arc<Mutex<Vec<u8>>>);

impl std::io::Write for SharedSink {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.0.lock().unwrap().extend_from_slice(buf);
        Ok(buf.len())
    }
    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

fn stormy_plan() -> FaultPlan {
    FaultPlan {
        drop_p: 0.25,
        corrupt_p: 0.25,
        truncate_p: 0.2,
        delay_p: 0.0,
        max_delay: Duration::from_millis(5),
    }
}

#[test]
fn fault_schedule_replays_exactly_per_seed() {
    let run = |seed: u64| {
        let sink = SharedSink::default();
        let counters = Arc::new(FaultCounters::default());
        let mut s = FaultyStream::new(
            sink.clone(),
            stormy_plan(),
            seed,
            Arc::new(AtomicBool::new(true)),
            Arc::clone(&counters),
        );
        for seq in 1..=30 {
            wire::write_frame(&mut s, &Frame::Heartbeat { seq }).unwrap();
        }
        use std::sync::atomic::Ordering;
        let tally = [
            counters.passed.load(Ordering::SeqCst),
            counters.dropped.load(Ordering::SeqCst),
            counters.corrupted.load(Ordering::SeqCst),
            counters.truncated.load(Ordering::SeqCst),
        ];
        (sink.0.lock().unwrap().clone(), tally)
    };
    let (bytes_a, tally_a) = run(11);
    let (bytes_b, tally_b) = run(11);
    assert_eq!(bytes_a, bytes_b, "same seed must replay identical bytes");
    assert_eq!(tally_a, tally_b);
    assert_eq!(tally_a.iter().sum::<u64>(), 30);
    assert!(
        tally_a[1] + tally_a[2] + tally_a[3] > 0,
        "storm plan injected nothing: {tally_a:?}"
    );
    let (bytes_c, _) = run(12);
    assert_ne!(bytes_a, bytes_c, "different seeds, different schedules");
}

#[test]
fn corrupted_frames_never_decode() {
    forall_seeds(10, |seed| {
        let sink = SharedSink::default();
        let mut s = FaultyStream::new(
            sink.clone(),
            FaultPlan { corrupt_p: 1.0, ..FaultPlan::default() },
            seed,
            Arc::new(AtomicBool::new(true)),
            Arc::new(FaultCounters::default()),
        );
        let mut g = Gen::new(seed);
        wire::write_frame(&mut s, &random_model_frame(&mut g)).unwrap();
        let bytes = sink.0.lock().unwrap().clone();
        assert!(
            Frame::decode(&bytes).is_err(),
            "a bit-flipped frame decoded cleanly"
        );
    });
}

#[test]
fn dropped_frames_leave_no_bytes() {
    let sink = SharedSink::default();
    let counters = Arc::new(FaultCounters::default());
    let mut s = FaultyStream::new(
        sink.clone(),
        FaultPlan { drop_p: 1.0, ..FaultPlan::default() },
        3,
        Arc::new(AtomicBool::new(true)),
        Arc::clone(&counters),
    );
    for seq in 1..=5 {
        wire::write_frame(&mut s, &Frame::Heartbeat { seq }).unwrap();
    }
    assert!(sink.0.lock().unwrap().is_empty());
    use std::sync::atomic::Ordering;
    assert_eq!(counters.dropped.load(Ordering::SeqCst), 5);
}

// ---------------------------------------------------------------------------
// follower under a fault storm

#[test]
fn follower_never_installs_torn_model_under_fault_storm() {
    let pub_addr = unix_addr("storm-pub");
    let proxy_addr = unix_addr("storm-proxy");
    let opts = fast_fabric();
    let bus = ModelBus::new();
    let publisher =
        SocketPublisher::spawn(&pub_addr, bus.clone(), Some(77), opts)
            .unwrap();
    let proxy = FaultyProxy::spawn(
        &proxy_addr,
        pub_addr.clone(),
        FaultPlan {
            drop_p: 0.2,
            corrupt_p: 0.2,
            truncate_p: 0.15,
            delay_p: 0.0,
            max_delay: Duration::from_millis(5),
        },
        9,
        opts,
    )
    .unwrap();
    let mut follower = SocketFollower::connect(proxy_addr, None, opts);

    let mut seen = Vec::new();
    for generation in 1..=40usize {
        bus.publish(versioned(generation), generation);
        std::thread::sleep(Duration::from_millis(5));
        drain_checked(&mut follower, &mut seen, Some(77));
    }
    // storm over: with a clean pipe the follower must converge on the
    // newest generation (reconnect catch-up delivers it even if every
    // live push was eaten)
    proxy.set_faults_enabled(false);
    bus.publish(versioned(41), 41);
    wait_until(
        "convergence to generation 41",
        Duration::from_secs(20),
        || {
            drain_checked(&mut follower, &mut seen, Some(77));
            seen.last() == Some(&41)
        },
    );

    use std::sync::atomic::Ordering;
    let c = proxy.counters();
    let injected = c.dropped.load(Ordering::SeqCst)
        + c.corrupted.load(Ordering::SeqCst)
        + c.truncated.load(Ordering::SeqCst);
    assert!(injected > 0, "storm must actually injure frames");
    if c.corrupted.load(Ordering::SeqCst)
        + c.truncated.load(Ordering::SeqCst)
        > 0
    {
        assert!(
            follower.status().reconnects >= 1,
            "injured frames must force at least one reconnect"
        );
    }
    assert!(publisher.accepted() >= 1);

    // clean shutdown propagates end-of-stream through the proxy
    bus.close();
    wait_until("publisher shutdown", Duration::from_secs(10), || {
        follower.status().publisher_done
    });
}

#[test]
fn follower_reconnects_after_publisher_restart() {
    let addr = unix_addr("restart");
    let opts = fast_fabric();
    let mut seen = Vec::new();

    let bus1 = ModelBus::new();
    let p1 =
        SocketPublisher::spawn(&addr, bus1.clone(), None, opts).unwrap();
    let mut follower = SocketFollower::connect(addr.clone(), None, opts);
    bus1.publish(versioned(2), 2);
    wait_until("first model", Duration::from_secs(10), || {
        drain_checked(&mut follower, &mut seen, None);
        seen.last() == Some(&2)
    });

    // crash: no Shutdown frame, the socket just dies
    drop(p1);
    wait_until("disconnect detected", Duration::from_secs(10), || {
        !follower.status().connected
    });
    // degraded: last-good model keeps serving (no poll regression)
    drain_checked(&mut follower, &mut seen, None);
    assert_eq!(seen.last(), Some(&2));

    // restarted trainer on the same address, further along
    let bus2 = ModelBus::new();
    let _p2 =
        SocketPublisher::spawn(&addr, bus2.clone(), None, opts).unwrap();
    bus2.publish(versioned(5), 5);
    wait_until("model after restart", Duration::from_secs(10), || {
        drain_checked(&mut follower, &mut seen, None);
        seen.last() == Some(&5)
    });
    assert!(follower.status().reconnects >= 1);

    bus2.close();
    wait_until("clean shutdown", Duration::from_secs(10), || {
        follower.status().publisher_done
    });
    assert_eq!(seen, vec![2, 5]);
}

// ---------------------------------------------------------------------------
// checkpoint-trail degradation

fn write_ckpt(dir: &Path, generation: usize) {
    let ckpt = Checkpoint {
        fingerprint: Fingerprint { config: 1, data: 2 },
        elapsed: Duration::ZERO,
        stop_reason: None,
        rounds: (0..generation)
            .map(|i| Round { feature: i, criterion: 1.0 })
            .collect(),
        selected: vec![0],
        weights: vec![generation as f64],
    };
    ckpt.save_atomic(&checkpoint::checkpoint_path(dir, generation))
        .unwrap();
}

fn temp_trail(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn follower_degrades_to_trail_and_recovers_to_wire() {
    let addr = unix_addr("trail");
    let dir = temp_trail("greedy_rls_fabric_trail_test");
    let opts = fast_fabric();

    // nothing is listening: the trail is the only source
    write_ckpt(&dir, 3);
    let mut follower =
        SocketFollower::connect(addr.clone(), Some(dir.clone()), opts);
    let mut rounds_seen = Vec::new();
    wait_until("trail fallback model", Duration::from_secs(10), || {
        while let Some(u) = follower.poll_model().unwrap() {
            assert_eq!(u.predictor.weights, vec![u.rounds as f64]);
            rounds_seen.push(u.rounds);
        }
        rounds_seen.last() == Some(&3)
    });

    // publisher appears: the wire takes over
    let bus = ModelBus::new();
    let publisher =
        SocketPublisher::spawn(&addr, bus.clone(), None, opts).unwrap();
    bus.publish(versioned(5), 5);
    wait_until("wire takeover", Duration::from_secs(10), || {
        while let Some(u) = follower.poll_model().unwrap() {
            rounds_seen.push(u.rounds);
        }
        rounds_seen.last() == Some(&5)
    });

    // publisher dies again: anything newer it flushed to the trail
    // before dying is picked up
    drop(publisher);
    wait_until("disconnect detected", Duration::from_secs(10), || {
        !follower.status().connected
    });
    write_ckpt(&dir, 6);
    wait_until("trail resume", Duration::from_secs(10), || {
        while let Some(u) = follower.poll_model().unwrap() {
            rounds_seen.push(u.rounds);
        }
        rounds_seen.last() == Some(&6)
    });

    // a stale checkpoint older than the served model never surfaces
    write_ckpt(&dir, 4);
    for _ in 0..20 {
        assert!(
            follower.poll_model().unwrap().is_none(),
            "stale checkpoint regressed the served model"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    assert_eq!(rounds_seen, vec![3, 5, 6]);
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------------------------
// serve --listen admission control

#[test]
fn saturated_queues_shed_with_retry_after() {
    let addr = unix_addr("shed-raw");
    let server = Arc::new(HotSwapServer::new(versioned(1)));
    let front = ListenServer::spawn(
        &addr,
        Arc::clone(&server),
        ListenOptions {
            workers: 1,
            queue_depth: 1,
            retry_after_ms: 7,
            worker_delay: Duration::from_millis(400),
            fabric: fast_fabric(),
        },
    )
    .unwrap();

    let query =
        Frame::Query { rows: 1, cols: 4, values: vec![1.0, 1.0, 1.0, 1.0] };
    let mut c1 = client(&addr);
    let mut c2 = client(&addr);
    let mut c3 = client(&addr);
    // c1 occupies the single worker, c2 the single queue slot...
    wire::write_frame(&mut c1, &query).unwrap();
    std::thread::sleep(Duration::from_millis(50));
    wire::write_frame(&mut c2, &query).unwrap();
    std::thread::sleep(Duration::from_millis(50));
    // ...so c3 must be shed immediately with the configured retry-after
    wire::write_frame(&mut c3, &query).unwrap();
    match wire::read_frame(&mut c3).unwrap() {
        Frame::Overloaded { retry_after_ms } => {
            assert_eq!(retry_after_ms, 7)
        }
        other => panic!("expected Overloaded, got {other:?}"),
    }
    // shedding c3 cost the others nothing: both still answer
    for c in [&mut c1, &mut c2] {
        match wire::read_frame(c).unwrap() {
            Frame::Predictions { rounds: _, values } => {
                assert_eq!(values.len(), 4);
                assert_eq!(values[0].to_bits(), 1.0f64.to_bits());
            }
            other => panic!("expected Predictions, got {other:?}"),
        }
    }
    let counts = front.counts();
    assert_eq!(counts.shed, 1);
    assert_eq!(counts.answered, 2);
}

#[test]
fn load_generator_report_matches_server_counters() {
    let addr = unix_addr("load");
    let server = Arc::new(HotSwapServer::new(versioned(2)));
    let front = ListenServer::spawn(
        &addr,
        Arc::clone(&server),
        ListenOptions {
            workers: 2,
            queue_depth: 2,
            retry_after_ms: 5,
            worker_delay: Duration::ZERO,
            fabric: fast_fabric(),
        },
    )
    .unwrap();
    let x = Matrix::from_vec(1, 64, vec![1.0; 64]);
    let report = run_load(
        &addr,
        &x,
        &LoadOptions {
            connections: 3,
            queries_per_conn: 20,
            batch: 8,
            qps: 0.0,
            seed: 7,
            fabric: fast_fabric(),
        },
    )
    .unwrap();
    assert_eq!(report.sent, 60);
    assert_eq!(report.errors, 0);
    assert_eq!(report.refused, 0);
    assert_eq!(report.answered + report.shed, report.sent);
    assert!(report.answered > 0);
    let counts = front.counts();
    assert_eq!(counts.answered, report.answered);
    assert_eq!(counts.shed, report.shed);
    assert!(report.p99_ms >= report.p50_ms);
}

#[test]
fn narrow_queries_are_refused_not_answered() {
    let addr = unix_addr("refuse");
    let server = Arc::new(HotSwapServer::new(Predictor {
        selected: vec![5],
        weights: vec![2.0],
    }));
    let _front = ListenServer::spawn(
        &addr,
        server,
        ListenOptions { fabric: fast_fabric(), ..ListenOptions::default() },
    )
    .unwrap();
    let mut c = client(&addr);
    let query = Frame::Query { rows: 2, cols: 3, values: vec![0.0; 6] };
    wire::write_frame(&mut c, &query).unwrap();
    match wire::read_frame(&mut c).unwrap() {
        Frame::Refused { reason } => {
            assert!(reason.contains("feature"), "{reason}")
        }
        other => panic!("expected Refused, got {other:?}"),
    }
}

#[test]
fn model_request_returns_bit_exact_current_model() {
    let addr = unix_addr("modelreq");
    let server = Arc::new(HotSwapServer::new(versioned(1)));
    server.swap(versioned(9), 9);
    let _front = ListenServer::spawn(
        &addr,
        Arc::clone(&server),
        ListenOptions { fabric: fast_fabric(), ..ListenOptions::default() },
    )
    .unwrap();
    let mut c = client(&addr);
    wire::write_frame(&mut c, &Frame::ModelRequest).unwrap();
    match wire::read_frame(&mut c).unwrap() {
        Frame::Model(m) => {
            assert_eq!(m.rounds, 9);
            assert_eq!(m.predictor.selected, vec![0]);
            assert_eq!(m.predictor.weights[0].to_bits(), 9.0f64.to_bits());
        }
        other => panic!("expected Model, got {other:?}"),
    }
}
