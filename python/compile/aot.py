"""AOT lowering: JAX entry points -> HLO text artifacts for the Rust runtime.

Interchange format is HLO *text*, NOT a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids, so text round-trips cleanly. (See /opt/xla-example/README.)

Every entry point is lowered with ``return_tuple=True`` so the Rust side
always unwraps a tuple, and at every (m, n) bucket listed in BUCKETS.
A manifest (artifacts/manifest.tsv) records entry name, file, shapes and
argument order so the runtime never guesses.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from . import model

# (m, n) shape buckets for the selection-loop entry points. The runtime
# pads a real (m, n) job into the smallest enclosing bucket; padding is
# exact (DESIGN.md §5). Buckets are kept modest because the CPU PJRT
# compile happens once per (entry, bucket) at coordinator startup.
BUCKETS = [
    (64, 128),
    (256, 256),
    (512, 1024),
    (1024, 2048),
]

# (k, t) buckets for the serving entry points.
PREDICT_BUCKETS = [(64, 256), (128, 1024)]
TRAIN_BUCKETS = [(64, 256), (128, 1024)]  # (k, m)

# Selection-loop entry points lowered at every (m, n) bucket. The first
# three drive forward greedy RLS; full_init_state/score_removal_step/
# downdate_step add backward elimination (and the backward phases of
# FoBa/floating); the nfold_* pair adds the n-fold-CV criterion. The
# nfold entries additionally carry their static fold capacity as extra
# manifest columns (f=FOLD_FMAX, s=fold_smax(m)) so the Rust runtime can
# check fold fit without mirroring the sizing formula.
SELECTION_ENTRIES = [
    "init_state",
    "full_init_state",
    "score_step",
    "score_removal_step",
    "commit_step",
    "downdate_step",
    "nfold_score_step",
    "nfold_commit_step",
]


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_entry(entry: str, *shape_args) -> str:
    fn = model.ENTRY_POINTS[entry]
    lowered = jax.jit(fn).lower(*model.example_args(entry, *shape_args))
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--buckets",
        default=None,
        help="comma list of MxN selection buckets, e.g. 256x256,1024x2048",
    )
    args = ap.parse_args()

    buckets = BUCKETS
    if args.buckets:
        buckets = [
            tuple(int(x) for x in b.split("x")) for b in args.buckets.split(",")
        ]

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = []

    for m, n in buckets:
        for entry in SELECTION_ENTRIES:
            name = f"{entry}_m{m}_n{n}"
            text = lower_entry(entry, m, n)
            path = os.path.join(args.out_dir, f"{name}.hlo.txt")
            with open(path, "w") as fh:
                fh.write(text)
            row = [entry, f"{name}.hlo.txt", f"m={m}", f"n={n}"]
            if entry.startswith("nfold_"):
                row += [f"f={model.FOLD_FMAX}", f"s={model.fold_smax(m)}"]
            manifest.append(tuple(row))
            print(f"wrote {path}  ({len(text)} chars)")

    for k, t in PREDICT_BUCKETS:
        name = f"predict_k{k}_t{t}"
        lowered = jax.jit(model.predict).lower(
            *model.example_args("predict", 0, 0, k=k, t=t)
        )
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as fh:
            fh.write(to_hlo_text(lowered))
        manifest.append(("predict", f"{name}.hlo.txt", f"k={k}", f"t={t}"))
        print(f"wrote {path}")

    for k, m in TRAIN_BUCKETS:
        name = f"train_dual_k{k}_m{m}"
        lowered = jax.jit(model.train_dual).lower(
            *model.example_args("train_dual", m, 0, k=k)
        )
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as fh:
            fh.write(to_hlo_text(lowered))
        manifest.append(("train_dual", f"{name}.hlo.txt", f"k={k}", f"m={m}"))
        print(f"wrote {path}")

    with open(os.path.join(args.out_dir, "manifest.tsv"), "w") as fh:
        fh.write("# entry\tfile\tdim1\tdim2\tdtype=f64\treturn_tuple=1\n")
        for row in manifest:
            fh.write("\t".join(row) + "\n")
    print(f"manifest: {len(manifest)} artifacts")


if __name__ == "__main__":
    main()
