"""Layer-2 JAX compute graph for greedy RLS.

The paper's contribution is a training/selection algorithm, so the "model"
here is the per-round compute of Algorithm 3, expressed as four jittable
entry points that Layer 3 (the Rust coordinator) drives:

    init_state   (X, y)            -> (C0, a0, d0)      caches for S = {}
    score_step   (X, C, a, d, y,
                  cand_mask, ex_mask) -> (e_sq, e_01)   LOO error per candidate
    commit_step  (X, C, a, d, b)   -> (C', a', d')      add feature b to S
    predict      (w, Xtest)        -> scores            serve a sparse model

score_step and commit_step call the Layer-1 Pallas kernels so that the hot
O(mn) work lowers through the same HLO the kernels define. Everything here
is shape-static; aot.py lowers each entry point at a set of (m, n) buckets
and the Rust runtime pads + masks real jobs into a bucket (DESIGN.md §5 —
padding is exact, not approximate).

All arrays are float64: the Rust native engine is f64 and the equivalence
tests require the two engines to pick identical feature sequences.
"""

from __future__ import annotations

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402

from .kernels import (  # noqa: E402
    FOLD_FMAX,
    fold_smax,
    loo_removal_scores,
    loo_scores,
    nfold_scores,
    rank1_update,
)

DTYPE = jnp.float64


def init_state(X, y, lam):
    """Caches for the empty feature set: C = X^T/lam, a = y/lam, d = 1/lam.

    lam arrives as a (1,) array so one artifact serves any regularization.
    """
    lam = lam[0]
    inv = 1.0 / lam
    C0 = X.T * inv
    a0 = y * inv
    d0 = jnp.full(y.shape, inv, dtype=X.dtype)
    return C0, a0, d0


def score_step(X, C, a, d, y, cand_mask, ex_mask):
    """LOO error (squared and zero-one) of S+{i} for every candidate i."""
    return loo_scores(X, C, a, d, y, cand_mask, ex_mask)


def commit_step(X, C, a, d, b):
    """Commit feature index b (int32 scalar) into the caches.

    v = X[b], c = C[:, b] are extracted with dynamic slices; the O(mn)
    rank-1 downdate of C runs through the Pallas update kernel.
    """
    return _commit_core(X, C, a, d, b)


def _commit_core(X, C, a, d, b):
    """Shared body of commit_step, reused by the full-set initializer."""
    n, m = X.shape
    b = b.astype(jnp.int32)
    v = jax.lax.dynamic_slice(X, (b, jnp.int32(0)), (1, m))[0]  # (m,)
    c = jax.lax.dynamic_slice(C, (jnp.int32(0), b), (m, 1))[:, 0]  # (m,)
    u = c / (1.0 + v @ c)
    a2 = a - u * (v @ a)
    d2 = d - u * c
    w = v @ C  # (n,) row vector v^T C
    C2 = rank1_update(C, u, w)
    return C2, a2, d2


def full_init_state(X, y, lam):
    """Caches for the FULL feature set (backward elimination's starting
    point): commit every feature into the empty-set caches with the same
    rank-1 SMW updates the selection itself uses, inside one launch.

    Padded feature rows are zero, so committing them is an exact no-op
    (v = 0 ⇒ u = 0) — the fori_loop runs over the whole bucket safely.
    Equivalent to G = (X^T X + lam I)^{-1}, C = G X^T, a = G y,
    d = diag(G) up to f64 rounding (the native engine inverts directly;
    the PJRT equivalence tests are tolerance-based for backward).
    """
    C, a, d = init_state(X, y, lam)
    n = X.shape[0]

    def body(i, state):
        C, a, d = state
        return _commit_core(X, C, a, d, jnp.int32(i))

    return jax.lax.fori_loop(0, n, body, (C, a, d))


def score_removal_step(X, C, a, d, y, mem_mask, ex_mask):
    """LOO error (squared and zero-one) of S \\ {i} for every member i —
    backward elimination's masked *removal* scoring (sign-flipped SMW)."""
    return loo_removal_scores(X, C, a, d, y, mem_mask, ex_mask)


def downdate_step(X, C, a, d, b):
    """Remove feature index b (int32 scalar) from the caches: the
    sign-flipped commit (K ← K − v vᵀ):

        u = C[:,b] / (1 − v·C[:,b]),  a ← a + u (v·a),  d ← d + u∘C[:,b],
        C ← C + u (vᵀ C)

    The O(mn) rank-1 update runs through the same Pallas update kernel as
    commit_step, with the update vector negated.
    """
    n, m = X.shape
    b = b.astype(jnp.int32)
    v = jax.lax.dynamic_slice(X, (b, jnp.int32(0)), (1, m))[0]
    c = jax.lax.dynamic_slice(C, (jnp.int32(0), b), (m, 1))[:, 0]
    u = c / (1.0 - v @ c)
    a2 = a + u * (v @ a)
    d2 = d + u * c
    w = v @ C
    C2 = rank1_update(C, -u, w)  # C + u w^T
    return C2, a2, d2


def nfold_score_step(X, C, a, y, B, fold_idx, fold_mask, cand_mask):
    """n-fold CV error of S ∪ {i} for every candidate — fold-masked
    scoring against the on-device fold-diagonal blocks B (see
    `kernels.nfold_kernel`)."""
    return nfold_scores(X, C, a, y, B, fold_idx, fold_mask, cand_mask)


def nfold_commit_step(X, C, a, B, fold_idx, fold_mask, b):
    """Commit feature b into the n-fold caches: the usual [C, a] rank-1
    update plus the fold-block downdate B_h ← B_h − u_H (c_H)ᵀ (the
    blocks transform exactly like d, restricted to fold slots)."""
    n, m = X.shape
    b = b.astype(jnp.int32)
    v = jax.lax.dynamic_slice(X, (b, jnp.int32(0)), (1, m))[0]
    c = jax.lax.dynamic_slice(C, (jnp.int32(0), b), (m, 1))[:, 0]
    u = c / (1.0 + v @ c)
    a2 = a - u * (v @ a)
    w = v @ C
    C2 = rank1_update(C, u, w)
    flat = fold_idx.reshape(-1)
    uH = u[flat].reshape(fold_idx.shape) * fold_mask
    cH = c[flat].reshape(fold_idx.shape) * fold_mask
    B2 = B - uH[:, :, None] * cH[:, None, :]
    return C2, a2, B2


def predict(w, Xtest):
    """Scores of a sparse linear predictor on a test batch.

    w: (k,) weights over the selected features (zero-padded to the bucket
    k); Xtest: (k, t) test batch laid out feature-major like X. Padding
    rows are zero so they contribute nothing.
    """
    return w @ Xtest


def _cg_solve(matvec, b, iters):
    """Conjugate gradients for an SPD system, fixed iteration count.

    jnp.linalg.solve / cholesky lower to LAPACK custom-calls with the
    TYPED_FFI API that xla_extension 0.5.1 cannot compile, so the AOT
    path solves the regularized normal equations with plain-HLO CG
    (`lax.fori_loop` of matvecs). λ-regularized systems are well
    conditioned; `iters` defaults to a safely convergent count and the
    pjrt integration test pins the result to the native Cholesky solve
    at 1e-7.
    """
    x0 = jnp.zeros_like(b)
    r0 = b  # b - A @ 0
    p0 = r0
    rs0 = r0 @ r0

    def body(_, state):
        x, r, p, rs = state
        ap = matvec(p)
        # guard against division by ~0 once converged
        denom = p @ ap
        alpha = jnp.where(denom > 0.0, rs / jnp.maximum(denom, 1e-300), 0.0)
        x = x + alpha * p
        r = r - alpha * ap
        rs_new = r @ r
        beta = jnp.where(rs > 0.0, rs_new / jnp.maximum(rs, 1e-300), 0.0)
        p = r + beta * p
        return (x, r, p, rs_new)

    x, _, _, _ = jax.lax.fori_loop(0, iters, body, (x0, r0, p0, rs0))
    return x


def train_dual(Xs, y, lam):
    """Dual RLS (eq. 4) on an already-selected feature matrix Xs (k, m):
    a = (Xs^T Xs + lam I)^{-1} y, w = Xs a. Padding feature rows are zero
    and padding examples must be masked by the caller *before* this call
    (zero rows + zero labels leave a unaffected on real coordinates).

    Exported so the serving path can refit a final predictor with a fresh
    lambda without Python. Returns (w, a).

    The solve is CG on K + λI (see [`_cg_solve`]); with k features the
    Gram matrix has rank ≤ k, so CG converges in ~k+1 exact-arithmetic
    steps — 4k + 32 iterations leave ample slack for f64 rounding.
    """
    lam = lam[0]
    k, m = Xs.shape

    def matvec(v):
        # (Xs^T Xs + lam I) v without materializing the m×m Gram matrix
        return Xs.T @ (Xs @ v) + lam * v

    a = _cg_solve(matvec, y, iters=4 * k + 32)
    w = Xs @ a
    return w, a


# Example-shape builders used by aot.py and the pytest suite ---------------


def example_args(entry: str, m: int, n: int, k: int = 64, t: int = 256):
    """ShapeDtypeStructs describing each entry point's signature."""
    f = lambda *s: jax.ShapeDtypeStruct(s, DTYPE)  # noqa: E731
    fm, fs = FOLD_FMAX, fold_smax(m)
    i32 = lambda *s: jax.ShapeDtypeStruct(s, jnp.int32)  # noqa: E731
    if entry in ("init_state", "full_init_state"):
        return (f(n, m), f(m), f(1))
    if entry in ("score_step", "score_removal_step"):
        return (f(n, m), f(m, n), f(m), f(m), f(m), f(n), f(m))
    if entry in ("commit_step", "downdate_step"):
        return (f(n, m), f(m, n), f(m), f(m), i32())
    if entry == "nfold_score_step":
        return (f(n, m), f(m, n), f(m), f(m), f(fm, fs, fs),
                i32(fm, fs), f(fm, fs), f(n))
    if entry == "nfold_commit_step":
        return (f(n, m), f(m, n), f(m), f(fm, fs, fs),
                i32(fm, fs), f(fm, fs), i32())
    if entry == "predict":
        return (f(k), f(k, t))
    if entry == "train_dual":
        return (f(k, m), f(m), f(1))
    raise ValueError(f"unknown entry point {entry!r}")


ENTRY_POINTS = {
    "init_state": init_state,
    "full_init_state": full_init_state,
    "score_step": score_step,
    "score_removal_step": score_removal_step,
    "commit_step": commit_step,
    "downdate_step": downdate_step,
    "nfold_score_step": nfold_score_step,
    "nfold_commit_step": nfold_commit_step,
    "predict": predict,
    "train_dual": train_dual,
}
