"""Layer-1 Pallas kernels for greedy RLS + pure-jnp reference oracles."""

from . import ref  # noqa: F401
from .nfold_kernel import FOLD_FMAX, fold_smax, nfold_scores  # noqa: F401
from .score_kernel import loo_removal_scores, loo_scores  # noqa: F401
from .update_kernel import rank1_update  # noqa: F401
