"""Layer-1 compute for the n-fold CV scoring step (fold-masked scoring).

The n-fold greedy selector (`rust/src/select/nfold.rs`) scores candidate i
with the hold-out shortcut: per fold H, the held-out predictions are

    p_H = y_H - B~^{-1} a~_H,    B~ = B_H - u_H c_H^T,  a~_H = a_H - u_H (v.a)

where B_H = G[H, H] is the fold-diagonal block of G, maintained on-device
as a third state tensor alongside [C, a]. Unlike the LOO kernels, the hot
work here is not a pure streaming elementwise pass — every candidate needs
an s x s SPD solve per fold — so this module is plain shape-static JAX
rather than Pallas: the O(mn) part (the v.c / v.a dots) lowers to the same
HLO dot shapes as the score kernel, and the fold solves are batched CG
(plain HLO — LAPACK custom-calls are unavailable to the AOT path, see
`model._cg_solve`).

Static fold capacity: fold tensors are padded to (FMAX, smax) slots.
Padded slots carry fold_mask 0 and index 0; masked block entries are
replaced by identity rows so the padded coordinates decouple from the
solve and contribute nothing to any loss (the same exact-padding argument
as DESIGN.md §5). Candidate blocking bounds the (f, s, s, block) solve
temporary; `_block_n` picks the largest divisor of n within the memory
target.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .ref import BIG

# Fold-capacity constants shared with aot.py (manifest extra columns) and
# mirrored by the Rust engine's begin-time capacity checks.
FOLD_FMAX = 16


def fold_smax(m: int) -> int:
    """Per-fold slot capacity at example bucket size m.

    Sized so the default 10-fold split of any m <= bucket fits
    (ceil(m/10) < m/8 for m >= 80; the max(16, ...) floor covers small
    buckets), while keeping the s^2 block solves far below the O(mn) scan.
    """
    return max(16, m // 8)


def _block_n(n: int, f: int, s: int, budget: int = 1 << 22) -> int:
    """Largest divisor of n keeping the (f, s, s, block) temporary under
    ``budget`` elements."""
    bn = max(1, min(n, budget // max(1, f * s * s)))
    while n % bn != 0:
        bn -= 1
    return bn


def _cg_batch(Bt, rhs, iters: int):
    """Batched CG: solve Bt z = rhs for every (fold, candidate) pair.

    Bt: (f, s, s, b) SPD blocks; rhs: (f, s, b). Fixed iteration count
    (exact CG needs s steps; the slack absorbs f64 rounding), with the
    same converged-denominator guards as `model._cg_solve`.

    Returns ``(x, rs_final, rs0)`` — the final and initial squared
    residual norms, (f, b) — so the caller can detect solves that never
    converged (a singular / non-SPD block, the case where the native
    engine's Cholesky factorization fails).
    """

    def matvec(p):
        return jnp.einsum("frcb,fcb->frb", Bt, p)

    x0 = jnp.zeros_like(rhs)
    r0 = rhs
    p0 = r0
    rs0 = jnp.sum(r0 * r0, axis=1)  # (f, b)

    def body(_, state):
        x, r, p, rs = state
        ap = matvec(p)
        denom = jnp.sum(p * ap, axis=1)
        alpha = jnp.where(denom > 0.0, rs / jnp.maximum(denom, 1e-300), 0.0)
        x = x + alpha[:, None, :] * p
        r = r - alpha[:, None, :] * ap
        rs_new = jnp.sum(r * r, axis=1)
        beta = jnp.where(rs > 0.0, rs_new / jnp.maximum(rs, 1e-300), 0.0)
        p = r + beta[:, None, :] * p
        return (x, r, p, rs_new)

    x, r, _, rs = jax.lax.fori_loop(0, iters, body, (x0, r0, p0, rs0))
    del r
    return x, rs, rs0


def nfold_scores(X, C, a, y, B, fold_idx, fold_mask, cand_mask):
    """n-fold CV error of S ∪ {i} for every candidate i.

    Args:
        X: (n, m) feature matrix.
        C: (m, n) cache matrix G X^T.
        a: (m,) dual variables G y.
        y: (m,) labels.
        B: (f, s, s) fold-diagonal blocks of G (padded slots arbitrary —
            they are masked to identity before the solve).
        fold_idx: (f, s) int32 member indices, 0 in padded slots.
        fold_mask: (f, s) 1.0 for real fold slots, 0.0 for padding
            (entirely-padded folds are all-zero rows).
        cand_mask: (n,) 1.0 for evaluable candidates.

    Returns:
        (e_sq, e_01): (n,) summed squared / zero-one hold-out losses;
        masked candidates score BIG.
    """
    n, m = X.shape
    f, s = fold_idx.shape
    flat = fold_idx.reshape(-1)
    # c_i gathered at the fold slots, for every candidate: (f, s, n)
    cH_all = C[flat, :].reshape(f, s, n)
    aH = (a[flat] * fold_mask.reshape(-1)).reshape(f, s)
    yH = y[flat].reshape(f, s)

    vc = jnp.sum(X * C.T, axis=1)  # (n,)
    va = X @ a  # (n,)
    denom = 1.0 + vc

    eye = jnp.eye(s, dtype=X.dtype)
    m2 = fold_mask[:, :, None] * fold_mask[:, None, :]  # (f, s, s)
    pad_eye = (1.0 - m2) * eye[None, :, :]

    bn = _block_n(n, f, s)
    blocks = jnp.arange(n).reshape(n // bn, bn)

    big = jnp.asarray(BIG, dtype=X.dtype)

    def one_block(idx):
        cb = cH_all[:, :, idx]  # (f, s, bn)
        u = cb / denom[idx][None, None, :]
        Bt = B[:, :, :, None] - u[:, :, None, :] * cb[:, None, :, :]
        Bt = Bt * m2[..., None] + pad_eye[..., None]
        rhs = (aH[:, :, None] - u * va[idx][None, None, :]) \
            * fold_mask[:, :, None]
        z, rs_fin, rs0 = _cg_batch(Bt, rhs, s + 16)
        p = yH[:, :, None] - z  # hold-out predictions
        # residual y - p is z itself
        e_sq = jnp.sum(fold_mask[:, :, None] * z * z, axis=(0, 1))
        wrong = jnp.where((yH[:, :, None] * p) > 0.0, 0.0, 1.0)
        e_01 = jnp.sum(fold_mask[:, :, None] * wrong, axis=(0, 1))
        # a solve that never converged means the block would not factor —
        # the native engine's Cholesky-failure path; the candidate is not
        # evaluable this round (any fold failing poisons the candidate,
        # exactly like the native early return of BIG)
        # ~(<=) rather than (>) so NaN residuals (a degenerate u = c/0
        # candidate) also register as unsolved instead of leaking NaN
        unsolved = ~(rs_fin <= 1e-12 * (rs0 + 1e-300))  # (f, bn)
        bad = jnp.any(unsolved, axis=0)  # (bn,)
        return (
            jnp.where(bad, big, e_sq),
            jnp.where(bad, big, e_01),
        )

    e_sq, e_01 = jax.lax.map(one_block, blocks)
    e_sq = e_sq.reshape(n)
    e_01 = e_01.reshape(n)
    big = jnp.asarray(BIG, dtype=X.dtype)
    return (
        jnp.where(cand_mask > 0, e_sq, big),
        jnp.where(cand_mask > 0, e_01, big),
    )
