"""Layer-1 Pallas kernel: LOO scoring of every candidate feature.

This is the hot spot of greedy RLS: one selection round evaluates all n
candidate features against the current caches (C, a, d) in O(mn) work.

TPU mapping (DESIGN.md §Hardware-Adaptation): the candidate dimension n is
tiled into blocks of ``block_n`` columns. Each grid step holds in VMEM

    X block : (block_n, m)   the candidate feature value vectors v_i
    C block : (m, block_n)   the cached columns C[:, i] = (G X^T)[:, i]
    a, d, y, ex_mask : (m,)  broadcast to every candidate in the block

and produces two (block_n,) score rows. The per-candidate math is pure
element-wise VPU work plus an m-reduction — G (m x m) is never formed,
which is exactly the paper's memory insight restated as a BlockSpec.

VMEM budget per grid step at f32, m = 2048, block_n = 128:
    X block 1 MiB + C block 1 MiB + vectors ~32 KiB + (m, block_n)
    temporaries ~3 MiB  =>  ~5 MiB, comfortably inside the ~16 MiB/core
    budget; block_n is the single tuning knob if m grows.

interpret=True is mandatory here: the environment's PJRT CPU plugin cannot
run Mosaic custom-calls, so the kernel lowers to plain HLO. The BlockSpec
structure is still the real-TPU schedule.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import BIG


def _make_score_block(sign: float):
    """Build the per-block scoring kernel for one SMW direction.

    ``sign = +1.0`` scores *additions* (S ∪ {i}, the forward kernel):

        denom = 1 + v.c,   u = c/denom,   a~ = a - u (v.a),   d~ = d - u*c

    ``sign = -1.0`` scores *removals* (S \\ {i}, backward elimination):

        denom = 1 - v.c,   u = c/denom,   a~ = a + u (v.a),   d~ = d + u*c

    i.e. every occurrence of v.c and u flips sign — the sign-flipped SMW
    identity of `rust/src/select/backward.rs`. Removals additionally guard
    |denom| < 1e-12 (numerically unremovable this round → BIG), mirroring
    the native engine exactly.
    """

    def _score_block(x_ref, c_ref, a_ref, d_ref, y_ref, cmask_ref, emask_ref,
                     e_sq_ref, e_01_ref):
        """One block of candidates: compute both loss rows.

        Shapes inside the kernel:
            x_ref     (block_n, m)
            c_ref     (m, block_n)
            a/d/y/emask_ref (m,)
            cmask_ref (block_n,)
            e_*_ref   (block_n,)
        """
        xb = x_ref[...]
        cb = c_ref[...]
        a = a_ref[...]
        d = d_ref[...]
        y = y_ref[...]
        emask = emask_ref[...]
        cmask = cmask_ref[...]

        # v_i . C[:, i] and v_i . a for every candidate i in the block.
        vc = jnp.sum(xb * cb.T, axis=1)  # (block_n,)
        va = xb @ a  # (block_n,)

        denom = 1.0 + sign * vc
        bad = jnp.abs(denom) < 1e-12  # only reachable for sign = -1
        safe = jnp.where(bad, 1.0, denom)
        u = cb / safe[None, :]  # (m, block_n)
        a_t = a[:, None] - sign * u * va[None, :]  # updated dual variables
        d_t = d[:, None] - sign * u * cb  # updated diag(G)
        p = y[:, None] - a_t / d_t  # LOO predictions

        resid = y[:, None] - p
        e_sq = jnp.sum(emask[:, None] * resid * resid, axis=0)
        wrong = jnp.where((y[:, None] * p) > 0.0, 0.0, 1.0)
        e_01 = jnp.sum(emask[:, None] * wrong, axis=0)

        big = jnp.asarray(BIG, dtype=e_sq.dtype)
        keep = (cmask > 0) & ~bad
        e_sq_ref[...] = jnp.where(keep, e_sq, big)
        e_01_ref[...] = jnp.where(keep, e_01, big)

    return _score_block


_score_block = _make_score_block(1.0)
_removal_score_block = _make_score_block(-1.0)


def _blocked_scores(kernel, X, C, a, d, y, cand_mask, ex_mask, block_n):
    """Shared pallas_call plumbing for both scoring directions."""
    n, m = X.shape
    if n % block_n != 0:
        # Fall back to one block over everything (tiny test shapes).
        block_n = n
    grid = (n // block_n,)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, m), lambda i: (i, 0)),  # X
            pl.BlockSpec((m, block_n), lambda i: (0, i)),  # C
            pl.BlockSpec((m,), lambda i: (0,)),  # a
            pl.BlockSpec((m,), lambda i: (0,)),  # d
            pl.BlockSpec((m,), lambda i: (0,)),  # y
            pl.BlockSpec((block_n,), lambda i: (i,)),  # cand_mask
            pl.BlockSpec((m,), lambda i: (0,)),  # ex_mask
        ],
        out_specs=[
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), X.dtype),
            jax.ShapeDtypeStruct((n,), X.dtype),
        ],
        interpret=True,
    )(X, C, a, d, y, cand_mask, ex_mask)


@functools.partial(jax.jit, static_argnames=("block_n",))
def loo_scores(X, C, a, d, y, cand_mask, ex_mask, *, block_n: int = 128):
    """Pallas-blocked LOO scores of S ∪ {i} for all candidates.

    Args:
        X: (n, m) feature matrix (feature-major, as in the paper).
        C: (m, n) cache matrix G X^T.
        a: (m,) dual variables.
        d: (m,) diag(G).
        y: (m,) labels.
        cand_mask: (n,) 1.0 for evaluable candidates, 0.0 for
            already-selected / padded features (scored BIG).
        ex_mask: (m,) 1.0 for real examples, 0.0 for padding rows.
        block_n: candidate-dimension tile size; n must be divisible by it
            (the AOT buckets guarantee this; tests sweep odd sizes via the
            runtime's padding path).

    Returns:
        (e_sq, e_01): each (n,), the summed squared / zero-one LOO losses.
    """
    return _blocked_scores(
        _score_block, X, C, a, d, y, cand_mask, ex_mask, block_n
    )


@functools.partial(jax.jit, static_argnames=("block_n",))
def loo_removal_scores(X, C, a, d, y, mem_mask, ex_mask, *,
                       block_n: int = 128):
    """Pallas-blocked LOO scores of S \\ {i} for every member i.

    Same signature as [`loo_scores`] with the candidate mask replaced by a
    *membership* mask (1.0 for features currently in S), and the
    sign-flipped SMW inside the block (see [`_make_score_block`]). Members
    whose removal is numerically unrepresentable this round
    (|1 − v.c| < 1e-12) score BIG, exactly like the native engine.
    """
    return _blocked_scores(
        _removal_score_block, X, C, a, d, y, mem_mask, ex_mask, block_n
    )
