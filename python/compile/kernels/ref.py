"""Pure-jnp / numpy oracles for the greedy-RLS kernels.

These are the CORE correctness signal for Layer 1: every Pallas kernel in
this package must agree with the functions here (pytest enforces it, with
hypothesis sweeping shapes / dtypes / regularization).

Notation follows the paper (Pahikkala, Airola, Salakoski 2010):

    X  : (n, m)  feature matrix, X[i, j] = value of feature i on example j
    y  : (m,)    labels (+-1 for classification, real for regression)
    C  : (m, n)  cache matrix  C = G X^T,  G = (K + lam I)^{-1}
    a  : (m,)    dual variables  a = G y
    d  : (m,)    diag(G)

For the empty feature set, K = 0 so G = I/lam and the caches initialize to
    C0 = X^T / lam,   a0 = y / lam,   d0 = 1/lam.

Scoring a candidate feature i (eqs. 14, 15, 17 and (8) of the paper):

    v      = X[i, :]
    c      = C[:, i]
    u      = c / (1 + v.c)
    a~     = a - u (v.a)
    d~     = d - u * c
    p_j    = y_j - a~_j / d~_j          (LOO prediction for example j)
    e_i    = sum_j loss(y_j, p_j)

Committing the winning feature b (SMW rank-1 downdate of G):

    a <- a~,  d <- d~,  C <- C - u (v^T C)
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

BIG = 1e30  # sentinel for masked-out candidates (avoids inf-arithmetic NaNs)


# ---------------------------------------------------------------------------
# Candidate scoring
# ---------------------------------------------------------------------------


def loo_scores_ref(X, C, a, d, y, cand_mask, ex_mask):
    """LOO error of S+{i} for every candidate i, vectorized over features.

    Returns (e_sq, e_01):
      e_sq[i] = sum_j ex_mask[j] * (y_j - p_j)^2
      e_01[i] = sum_j ex_mask[j] * [y_j * p_j <= 0]   (an example predicted
                exactly 0 counts as an error)
    Candidates with cand_mask == 0 score BIG in both outputs.
    """
    X = jnp.asarray(X)
    C = jnp.asarray(C)
    vc = jnp.sum(X * C.T, axis=1)  # (n,)  v_i . C[:, i]
    va = X @ a  # (n,)  v_i . a
    denom = 1.0 + vc
    U = C / denom[None, :]  # (m, n) u vectors, one per candidate
    A = a[:, None] - U * va[None, :]  # (m, n) updated dual variables
    D = d[:, None] - U * C  # (m, n) updated diag(G)
    P = y[:, None] - A / D  # (m, n) LOO predictions
    resid = y[:, None] - P
    e_sq = jnp.sum(ex_mask[:, None] * resid * resid, axis=0)
    correct = (y[:, None] * P) > 0.0
    e_01 = jnp.sum(ex_mask[:, None] * jnp.where(correct, 0.0, 1.0), axis=0)
    big = jnp.asarray(BIG, dtype=e_sq.dtype)
    e_sq = jnp.where(cand_mask > 0, e_sq, big)
    e_01 = jnp.where(cand_mask > 0, e_01, big)
    return e_sq, e_01


def removal_scores_ref(X, C, a, d, y, mem_mask, ex_mask):
    """LOO error of S \\ {i} for every member i (sign-flipped SMW).

    Mirrors `rust/src/select/backward.rs::removal_score`: members with
    |1 - v.c| < 1e-12 (numerically unremovable this round) score BIG, as
    do non-members (mem_mask == 0).
    """
    X = jnp.asarray(X)
    C = jnp.asarray(C)
    vc = jnp.sum(X * C.T, axis=1)  # (n,)
    va = X @ a  # (n,)
    denom = 1.0 - vc
    bad = jnp.abs(denom) < 1e-12
    safe = jnp.where(bad, 1.0, denom)
    U = C / safe[None, :]  # (m, n)
    A = a[:, None] + U * va[None, :]
    D = d[:, None] + U * C
    P = y[:, None] - A / D
    resid = y[:, None] - P
    e_sq = jnp.sum(ex_mask[:, None] * resid * resid, axis=0)
    correct = (y[:, None] * P) > 0.0
    e_01 = jnp.sum(ex_mask[:, None] * jnp.where(correct, 0.0, 1.0), axis=0)
    big = jnp.asarray(BIG, dtype=e_sq.dtype)
    keep = (mem_mask > 0) & ~bad
    return jnp.where(keep, e_sq, big), jnp.where(keep, e_01, big)


def downdate_ref(X, C, a, d, b):
    """Full removal of feature index b: returns (C', a', d')."""
    v = X[b, :]
    c = C[:, b]
    u = c / (1.0 - v @ c)
    a2 = a + u * (v @ a)
    d2 = d + u * c
    w = X[b, :] @ C
    C2 = C + u[:, None] * w[None, :]
    return C2, a2, d2


def subset_caches_np(X, y, lam, feats):
    """[C, a, d] caches for feature set `feats` by direct inversion:
    G = (X_S^T X_S + lam I)^{-1} (m x m), C = G X^T, a = G y, d = diag(G).

    C keeps all n columns (C[:, i] = G x_i for every candidate i),
    exactly like the incremental engines maintain it.
    """
    X = np.asarray(X, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64)
    m = X.shape[1]
    Xs = X[list(feats), :] if len(feats) else np.zeros((0, m))
    G = np.linalg.inv(Xs.T @ Xs + lam * np.eye(m))
    return G @ X.T, G @ y, np.diag(G).copy()


def full_caches_np(X, y, lam):
    """[C, a, d] caches of the FULL feature set (backward elimination's
    starting point) — [`subset_caches_np`] over every feature."""
    return subset_caches_np(X, y, lam, range(np.asarray(X).shape[0]))


# ---------------------------------------------------------------------------
# Rank-1 cache update
# ---------------------------------------------------------------------------


def rank1_update_ref(C, u, w):
    """C <- C - u w^T  (the commit-step cache update)."""
    return C - u[:, None] * w[None, :]


def commit_ref(X, C, a, d, b):
    """Full commit of feature index b: returns (C', a', d')."""
    v = X[b, :]
    c = C[:, b]
    u = c / (1.0 + v @ c)
    a2 = a - u * (v @ a)
    d2 = d - u * c
    w = X[b, :] @ C  # v^T C, shape (n,)
    C2 = rank1_update_ref(C, u, w)
    return C2, a2, d2


def init_state_ref(X, y, lam):
    """Caches for the empty feature set."""
    C0 = X.T / lam
    a0 = y / lam
    d0 = jnp.full(y.shape, 1.0 / lam, dtype=X.dtype)
    return C0, a0, d0


# ---------------------------------------------------------------------------
# Brute-force oracles (no shortcuts at all) — used only in tests
# ---------------------------------------------------------------------------


def rls_dual_train_np(Xs, y, lam):
    """Dual RLS (eq. 4): returns (a, G) with G = (Xs^T Xs + lam I)^{-1}."""
    Xs = np.asarray(Xs, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64)
    m = Xs.shape[1]
    K = Xs.T @ Xs
    G = np.linalg.inv(K + lam * np.eye(m))
    return G @ y, G


def brute_force_loo_np(Xs, y, lam):
    """LOO predictions by literally retraining m times (Algorithm 1 inner
    loop). Xs: (|S|, m). Returns p: (m,)."""
    Xs = np.asarray(Xs, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64)
    s, m = Xs.shape
    p = np.zeros(m)
    for j in range(m):
        keep = [t for t in range(m) if t != j]
        Xl = Xs[:, keep]
        yl = y[keep]
        # primal (eq. 3): w = (X X^T + lam I)^{-1} X y
        w = np.linalg.solve(Xl @ Xl.T + lam * np.eye(s), Xl @ yl)
        p[j] = w @ Xs[:, j]
    return p


def nfold_scores_np(X, y, lam, selected, folds, cand, classification=False):
    """n-fold CV error of `selected` ∪ {cand} by explicit hold-out
    retraining (no shortcuts): for each fold H, train RLS on the
    complement examples with the candidate feature set, predict H.

    `folds` is a list of index lists partitioning range(m)."""
    X = np.asarray(X, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64)
    feats = list(selected) + [cand]
    s = len(feats)
    e = 0.0
    for h in folds:
        train = [j for j in range(len(y)) if j not in h]
        Xl = X[np.ix_(feats, train)]
        yl = y[train]
        w = np.linalg.solve(Xl @ Xl.T + lam * np.eye(s), Xl @ yl)
        for j in h:
            p = w @ X[feats, j]
            if classification:
                e += 0.0 if (y[j] * p) > 0.0 else 1.0
            else:
                e += (y[j] - p) ** 2
    return e


def greedy_rls_np(X, y, lam, k, classification=False):
    """Reference greedy RLS (Algorithm 3 verbatim, numpy float64).

    Returns (selected_indices, w_dense) where w_dense is the n-vector with
    the learned weights scattered into selected positions.
    """
    X = np.asarray(X, dtype=np.float64)
    y = np.asarray(y, dtype=np.float64)
    n, m = X.shape
    a = y / lam
    d = np.full(m, 1.0 / lam)
    C = X.T / lam
    selected: list[int] = []
    for _ in range(k):
        best, best_e = -1, np.inf
        for i in range(n):
            if i in selected:
                continue
            v = X[i]
            c = C[:, i]
            u = c / (1.0 + v @ c)
            a2 = a - u * (v @ a)
            d2 = d - u * c
            p = y - a2 / d2
            if classification:
                e = float(np.sum((y * p) <= 0.0))
            else:
                e = float(np.sum((y - p) ** 2))
            if e < best_e:
                best_e, best = e, i
        v = X[best]
        c = C[:, best]
        u = c / (1.0 + v @ c)
        a = a - u * (v @ a)
        d = d - u * c
        C = C - np.outer(u, v @ C)
        selected.append(best)
    w = np.zeros(n)
    w[selected] = X[selected] @ a
    return selected, w
