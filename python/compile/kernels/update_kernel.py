"""Layer-1 Pallas kernel: rank-1 cache update  C <- C - u w^T.

The commit step of greedy RLS (Algorithm 3, line 29). This is the second
O(mn) operation per selection round; u = C[:, b] / (1 + v.C[:, b]) is an
m-vector and w = v^T C an n-vector, both computed by the caller (Layer 2),
so the kernel itself is a pure streaming rank-1 downdate.

TPU mapping: tile the n (column) dimension; each grid step updates an
(m, block_n) slab of C in place of a VMEM-resident tile, reading the
broadcast u once. Bandwidth-bound by design — one read + one write of C.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rank1_block(c_ref, u_ref, w_ref, out_ref):
    out_ref[...] = c_ref[...] - u_ref[...][:, None] * w_ref[...][None, :]


@functools.partial(jax.jit, static_argnames=("block_n",))
def rank1_update(C, u, w, *, block_n: int = 256):
    """C - u w^T, tiled over columns.

    Args:
        C: (m, n) cache matrix.
        u: (m,) update vector (already divided by 1 + v.c).
        w: (n,) row vector v^T C.
        block_n: column tile width; n must divide (AOT buckets guarantee).

    Returns: the updated (m, n) matrix.
    """
    m, n = C.shape
    if n % block_n != 0:
        block_n = n
    grid = (n // block_n,)
    return pl.pallas_call(
        _rank1_block,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, block_n), lambda i: (0, i)),
            pl.BlockSpec((m,), lambda i: (0,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((m, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((m, n), C.dtype),
        interpret=True,
    )(C, u, w)
