"""Engine-parity entry points: removal scoring, full-set init, n-fold.

These are the Layer-1/2 contracts behind the Rust PJRT engines for
backward elimination, FoBa/floating backward phases, and n-fold greedy.
Deliberately hypothesis-free (plain seeded numpy) so the suite runs in
minimal environments; shapes are small because every oracle here retrains
or inverts explicitly.
"""

from __future__ import annotations

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from compile import model  # noqa: E402
from compile.kernels import (  # noqa: E402
    loo_removal_scores,
    loo_scores,
    nfold_scores,
    ref,
)

BIG = ref.BIG


subset_caches_np = ref.subset_caches_np


def loo_errors_np(X, y, lam, feats):
    """(e_sq, e_01) of the model on feature set `feats` via the dual LOO
    shortcut on directly inverted caches (eq. 8)."""
    _, a, d = subset_caches_np(X, y, lam, feats)
    p = y - a / d
    e_sq = float(np.sum((y - p) ** 2))
    e_01 = float(np.sum(np.where((y * p) > 0.0, 0.0, 1.0)))
    return e_sq, e_01


def full_problem(seed, n, m, lam, classification=True):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, m))
    if classification:
        y = np.where(rng.normal(size=m) > 0, 1.0, -1.0)
    else:
        y = rng.normal(size=m)
    C, a, d = ref.full_caches_np(X, y, lam)
    return X, y, C, a, d


# ---------------------------------------------------------------------------
# Removal scoring + downdate (backward elimination)
# ---------------------------------------------------------------------------


def test_removal_scores_match_explicit_retraining():
    for seed in range(5):
        n, m, lam = 6, 9, 0.8
        X, y, C, a, d = full_problem(seed, n, m, lam)
        mem = np.ones(n)
        ex = np.ones(m)
        e_sq, e_01 = model.score_removal_step(
            jnp.asarray(X), jnp.asarray(C), jnp.asarray(a), jnp.asarray(d),
            jnp.asarray(y), jnp.asarray(mem), jnp.asarray(ex),
        )
        e_sq, e_01 = np.asarray(e_sq), np.asarray(e_01)
        for i in range(n):
            keep = [t for t in range(n) if t != i]
            want_sq, want_01 = loo_errors_np(X, y, lam, keep)
            assert abs(e_sq[i] - want_sq) <= 1e-7 * max(1.0, abs(want_sq)), (
                f"seed {seed} member {i}: {e_sq[i]} vs {want_sq}"
            )
            assert e_01[i] == want_01, f"seed {seed} member {i}"


def test_removal_kernel_matches_jnp_reference_and_masks():
    rng = np.random.default_rng(42)
    n, m, lam = 8, 11, 1.3
    X, y, C, a, d = full_problem(7, n, m, lam)
    mem = np.ones(n)
    mem[[2, 5]] = 0.0  # pretend two features already removed
    ex = np.ones(m)
    k_sq, k_01 = loo_removal_scores(
        jnp.asarray(X), jnp.asarray(C), jnp.asarray(a), jnp.asarray(d),
        jnp.asarray(y), jnp.asarray(mem), jnp.asarray(ex),
    )
    r_sq, r_01 = ref.removal_scores_ref(X, C, a, d, y, mem, ex)
    np.testing.assert_allclose(np.asarray(k_sq), np.asarray(r_sq), rtol=1e-12)
    np.testing.assert_allclose(np.asarray(k_01), np.asarray(r_01), rtol=0)
    assert np.asarray(k_sq)[2] == BIG and np.asarray(k_01)[5] == BIG
    _ = rng  # seeded for symmetry with the other tests


def test_removal_denominator_guard_scores_big():
    # engineered v.c == 1 exactly: the removal is numerically
    # unrepresentable this round and must score BIG, like the native engine
    n, m = 3, 4
    X = np.zeros((n, m))
    X[0, 0] = 1.0
    C = np.zeros((m, n))
    C[0, 0] = 1.0  # v_0 . C[:,0] = 1  =>  denom = 0
    a = np.ones(m)
    d = np.ones(m)
    y = np.ones(m)
    e_sq, e_01 = loo_removal_scores(
        jnp.asarray(X), jnp.asarray(C), jnp.asarray(a), jnp.asarray(d),
        jnp.asarray(y), jnp.ones(n), jnp.ones(m),
    )
    assert np.asarray(e_sq)[0] == BIG and np.asarray(e_01)[0] == BIG
    assert np.isfinite(np.asarray(e_sq)[1:]).all()


def test_downdate_step_matches_direct_subset_caches():
    for seed in (0, 3):
        n, m, lam = 5, 8, 1.1
        X, y, C, a, d = full_problem(seed, n, m, lam)
        b = 2
        C2, a2, d2 = model.downdate_step(
            jnp.asarray(X), jnp.asarray(C), jnp.asarray(a), jnp.asarray(d),
            jnp.asarray(b, dtype=jnp.int32),
        )
        keep = [t for t in range(n) if t != b]
        Cw, aw, dw = subset_caches_np(X, y, lam, keep)
        np.testing.assert_allclose(np.asarray(C2), Cw, atol=1e-9)
        np.testing.assert_allclose(np.asarray(a2), aw, atol=1e-9)
        np.testing.assert_allclose(np.asarray(d2), dw, atol=1e-9)


def test_full_init_state_matches_direct_inverse():
    n, m, lam = 7, 10, 0.6
    X, y, C, a, d = full_problem(11, n, m, lam)
    C0, a0, d0 = model.full_init_state(
        jnp.asarray(X), jnp.asarray(y), jnp.asarray([lam])
    )
    np.testing.assert_allclose(np.asarray(C0), C, atol=1e-9)
    np.testing.assert_allclose(np.asarray(a0), a, atol=1e-9)
    np.testing.assert_allclose(np.asarray(d0), d, atol=1e-9)


def test_full_init_state_padding_is_exact():
    n, m, lam = 4, 6, 1.0
    nb, mb = 8, 9
    X, y, _, _, _ = full_problem(13, n, m, lam)
    Xp = np.zeros((nb, mb))
    Xp[:n, :m] = X
    yp = np.zeros(mb)
    yp[:m] = y
    C, a, d = model.full_init_state(
        jnp.asarray(X), jnp.asarray(y), jnp.asarray([lam])
    )
    Cp, ap, dp = model.full_init_state(
        jnp.asarray(Xp), jnp.asarray(yp), jnp.asarray([lam])
    )
    np.testing.assert_array_equal(np.asarray(Cp)[:m, :n], np.asarray(C))
    np.testing.assert_array_equal(np.asarray(ap)[:m], np.asarray(a))
    np.testing.assert_array_equal(np.asarray(dp)[:m], np.asarray(d))
    # padded coordinates keep their empty-set values exactly
    assert (np.asarray(ap)[m:] == 0.0).all()
    np.testing.assert_array_equal(np.asarray(dp)[m:], np.full(mb - m, 1.0))


def test_backward_elimination_end_to_end_through_entries():
    # drive full backward elimination with only the AOT entry points and
    # compare every removal against explicit retraining
    n, m, lam, k = 7, 12, 0.9, 3
    X, y, _, _, _ = full_problem(21, n, m, lam, classification=False)
    C, a, d = model.full_init_state(
        jnp.asarray(X), jnp.asarray(y), jnp.asarray([lam])
    )
    mem = np.ones(n)
    removed = []
    while int(mem.sum()) > k:
        e_sq, _ = model.score_removal_step(
            jnp.asarray(X), C, a, d, jnp.asarray(y),
            jnp.asarray(mem), jnp.ones(m),
        )
        scores = np.asarray(e_sq)
        b = int(np.argmin(scores))
        # oracle: the same argmin over explicit retrained subsets
        want = np.full(n, np.inf)
        members = [i for i in range(n) if mem[i] > 0]
        for i in members:
            keep = [t for t in members if t != i]
            want[i], _ = loo_errors_np(X, y, lam, keep)
        assert b == int(np.argmin(want)), f"round {len(removed)}"
        assert abs(scores[b] - want[b]) <= 1e-7 * max(1.0, abs(want[b]))
        C, a, d = model.downdate_step(
            jnp.asarray(X), C, a, d, jnp.asarray(b, dtype=jnp.int32)
        )
        mem[b] = 0.0
        removed.append(b)
    assert len(set(removed)) == n - k


# ---------------------------------------------------------------------------
# n-fold CV scoring (fold-masked)
# ---------------------------------------------------------------------------


def fold_tensors(folds, f_cap, s_cap):
    """Pack a fold partition into (idx, mask) tensors with padded slots."""
    idx = np.zeros((f_cap, s_cap), dtype=np.int32)
    mask = np.zeros((f_cap, s_cap))
    for h, members in enumerate(folds):
        idx[h, : len(members)] = members
        mask[h, : len(members)] = 1.0
    return idx, mask


def nfold_state(X, y, lam, folds, f_cap, s_cap, commits=()):
    """[C, a, B] n-fold caches for `commits`, built through the entries."""
    n, m = X.shape
    C, a, _ = model.init_state(
        jnp.asarray(X), jnp.asarray(y), jnp.asarray([lam])
    )
    idx, mask = fold_tensors(folds, f_cap, s_cap)
    B = np.zeros((f_cap, s_cap, s_cap))
    for h in range(f_cap):
        B[h] = np.eye(s_cap) / lam
    B = jnp.asarray(B)
    for b in commits:
        C, a, B = model.nfold_commit_step(
            jnp.asarray(X), C, a, B, jnp.asarray(idx), jnp.asarray(mask),
            jnp.asarray(b, dtype=jnp.int32),
        )
    return C, a, B, idx, mask


def test_nfold_scores_match_explicit_holdout():
    n, m, lam = 5, 12, 1.3
    rng = np.random.default_rng(31)
    X = rng.normal(size=(n, m))
    y = rng.normal(size=m)
    folds = [[0, 3, 6, 9], [1, 4, 7, 10], [2, 5, 8, 11]]
    for commits in ([], [1], [1, 4]):
        C, a, B, idx, mask = nfold_state(X, y, lam, folds, 4, 6, commits)
        cmask = np.ones(n)
        for b in commits:
            cmask[b] = 0.0
        e_sq, _ = model.nfold_score_step(
            jnp.asarray(X), C, a, jnp.asarray(y), B,
            jnp.asarray(idx), jnp.asarray(mask), jnp.asarray(cmask),
        )
        e_sq = np.asarray(e_sq)
        for i in range(n):
            if cmask[i] == 0.0:
                assert e_sq[i] == BIG
                continue
            want = ref.nfold_scores_np(X, y, lam, commits, folds, i)
            assert abs(e_sq[i] - want) <= 1e-6 * max(1.0, abs(want)), (
                f"commits {commits} cand {i}: {e_sq[i]} vs {want}"
            )


def test_nfold_zero_one_loss_matches_explicit_holdout():
    n, m, lam = 4, 9, 0.7
    rng = np.random.default_rng(5)
    X = rng.normal(size=(n, m))
    y = np.where(rng.normal(size=m) > 0, 1.0, -1.0)
    folds = [[0, 1, 2], [3, 4, 5], [6, 7, 8]]
    C, a, B, idx, mask = nfold_state(X, y, lam, folds, 3, 3)
    _, e_01 = model.nfold_score_step(
        jnp.asarray(X), C, a, jnp.asarray(y), B,
        jnp.asarray(idx), jnp.asarray(mask), jnp.ones(n),
    )
    for i in range(n):
        want = ref.nfold_scores_np(
            X, y, lam, [], folds, i, classification=True
        )
        assert np.asarray(e_01)[i] == want, f"cand {i}"


def test_nfold_commit_blocks_match_direct_inverse():
    n, m, lam = 5, 8, 1.0
    rng = np.random.default_rng(17)
    X = rng.normal(size=(n, m))
    y = rng.normal(size=m)
    folds = [[0, 1, 2], [3, 4], [5, 6, 7]]
    C, a, B, idx, mask = nfold_state(X, y, lam, folds, 4, 4, commits=[2, 0])
    Cw, aw, _ = subset_caches_np(X, y, lam, [2, 0])
    G = np.linalg.inv(X[[2, 0], :].T @ X[[2, 0], :] + lam * np.eye(m))
    np.testing.assert_allclose(np.asarray(C), Cw, atol=1e-10)
    np.testing.assert_allclose(np.asarray(a), aw, atol=1e-10)
    for h, members in enumerate(folds):
        s = len(members)
        np.testing.assert_allclose(
            np.asarray(B)[h, :s, :s], G[np.ix_(members, members)],
            atol=1e-10,
        )


def test_nfold_singleton_folds_reduce_to_loo():
    # m folds of size 1: the CV criterion degenerates to LOO and must
    # match the forward score kernel on the same caches
    n, m, lam = 6, 7, 0.9
    rng = np.random.default_rng(23)
    X = rng.normal(size=(n, m))
    y = np.where(rng.normal(size=m) > 0, 1.0, -1.0)
    folds = [[j] for j in range(m)]
    C, a, B, idx, mask = nfold_state(X, y, lam, folds, m, 2, commits=[3])
    cmask = np.ones(n)
    cmask[3] = 0.0
    nf_sq, nf_01 = model.nfold_score_step(
        jnp.asarray(X), C, a, jnp.asarray(y), B,
        jnp.asarray(idx), jnp.asarray(mask), jnp.asarray(cmask),
    )
    # forward kernel needs d = diag(G), which the singleton blocks carry
    d = np.array([np.asarray(B)[j, 0, 0] for j in range(m)])
    lo_sq, lo_01 = loo_scores(
        jnp.asarray(X), C, a, jnp.asarray(d), jnp.asarray(y),
        jnp.asarray(cmask), jnp.ones(m),
    )
    np.testing.assert_allclose(
        np.asarray(nf_sq), np.asarray(lo_sq), rtol=1e-9
    )
    np.testing.assert_array_equal(np.asarray(nf_01), np.asarray(lo_01))


def test_nfold_padding_is_exact():
    # pad candidates, examples, fold slots, and whole folds: real
    # coordinates must match the unpadded run to f64 solver tolerance
    n, m, lam = 4, 6, 1.2
    nb, mb = 8, 10
    rng = np.random.default_rng(41)
    X = rng.normal(size=(n, m))
    y = rng.normal(size=m)
    folds = [[0, 1, 2], [3, 4, 5]]
    C, a, B, idx, mask = nfold_state(X, y, lam, folds, 2, 3)
    ref_sq, _ = model.nfold_score_step(
        jnp.asarray(X), C, a, jnp.asarray(y), B,
        jnp.asarray(idx), jnp.asarray(mask), jnp.ones(n),
    )
    Xp = np.zeros((nb, mb))
    Xp[:n, :m] = X
    yp = np.zeros(mb)
    yp[:m] = y
    Cp, ap, Bp, idxp, maskp = nfold_state(Xp, yp, lam, folds, 4, 5)
    cmaskp = np.zeros(nb)
    cmaskp[:n] = 1.0
    pad_sq, _ = model.nfold_score_step(
        jnp.asarray(Xp), Cp, ap, jnp.asarray(yp), Bp,
        jnp.asarray(idxp), jnp.asarray(maskp), jnp.asarray(cmaskp),
    )
    np.testing.assert_allclose(
        np.asarray(pad_sq)[:n], np.asarray(ref_sq), rtol=1e-9
    )
    assert (np.asarray(pad_sq)[n:] == BIG).all()


def test_nfold_commit_padding_is_exact():
    n, m, lam = 4, 6, 1.2
    nb, mb = 8, 10
    rng = np.random.default_rng(43)
    X = rng.normal(size=(n, m))
    y = rng.normal(size=m)
    folds = [[0, 2, 4], [1, 3, 5]]
    C, a, B, _, _ = nfold_state(X, y, lam, folds, 2, 3, commits=[1])
    Xp = np.zeros((nb, mb))
    Xp[:n, :m] = X
    yp = np.zeros(mb)
    yp[:m] = y
    Cp, ap, Bp, _, _ = nfold_state(Xp, yp, lam, folds, 3, 4, commits=[1])
    np.testing.assert_array_equal(
        np.asarray(Cp)[:m, :n], np.asarray(C)
    )
    np.testing.assert_array_equal(np.asarray(ap)[:m], np.asarray(a))
    for h in range(2):
        np.testing.assert_array_equal(
            np.asarray(Bp)[h, :3, :3], np.asarray(B)[h]
        )


def test_nfold_singular_block_scores_big():
    # engineered singular B~ for candidate 0: B = c0^2/(1 + x*c0) makes
    # B~ = B - u*c0 exactly 0, the case where the native engine's
    # Cholesky fails and returns BIG — the CG path must flag it too
    # rather than return a finite garbage score
    X = np.array([[1.0], [0.5]])  # n=2 candidates, m=1 example
    C = np.array([[1.0, 0.2]])  # (m, n); c0 = 1
    a = np.array([1.0])
    y = np.array([1.0])
    idx = np.array([[0]], dtype=np.int32)  # one fold of size 1
    mask = np.ones((1, 1))
    B = np.array([[[1.0 / (1.0 + 1.0 * 1.0)]]])  # = 0.5 ⇒ B~_0 = 0
    e_sq, e_01 = nfold_scores(
        jnp.asarray(X), jnp.asarray(C), jnp.asarray(a), jnp.asarray(y),
        jnp.asarray(B), jnp.asarray(idx), jnp.asarray(mask), jnp.ones(2),
    )
    assert np.asarray(e_sq)[0] == BIG and np.asarray(e_01)[0] == BIG
    # the well-posed candidate still scores finitely
    assert np.isfinite(np.asarray(e_sq)[1])
    assert np.asarray(e_sq)[1] < BIG


def test_fold_capacity_formula():
    # the Rust runtime reads these from the manifest; pin the formula so
    # regenerated artifacts stay compatible with committed expectations
    from compile.kernels import FOLD_FMAX, fold_smax

    assert FOLD_FMAX == 16
    assert fold_smax(64) == 16
    assert fold_smax(256) == 32
    assert fold_smax(512) == 64
    assert fold_smax(1024) == 128
