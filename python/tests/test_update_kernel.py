"""Pallas rank-1 update kernel vs the oracle, and SMW-identity checks."""

from __future__ import annotations

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402
from hypothesis import given, settings, strategies as st  # noqa: E402

from compile.kernels import rank1_update, ref  # noqa: E402

SETTINGS = dict(max_examples=20, deadline=None)


class TestRank1Kernel:
    @settings(**SETTINGS)
    @given(
        m=st.integers(1, 48),
        n=st.integers(1, 48),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, m, n, seed):
        rng = np.random.default_rng(seed)
        C = rng.normal(size=(m, n))
        u = rng.normal(size=m)
        w = rng.normal(size=n)
        got = rank1_update(jnp.asarray(C), jnp.asarray(u), jnp.asarray(w))
        np.testing.assert_allclose(
            got, ref.rank1_update_ref(C, u, w), rtol=1e-12, atol=1e-12
        )

    @pytest.mark.parametrize("block_n", [1, 3, 16, 256])
    def test_block_sizes(self, block_n):
        rng = np.random.default_rng(0)
        C = rng.normal(size=(7, 12))
        u = rng.normal(size=7)
        w = rng.normal(size=12)
        got = rank1_update(
            jnp.asarray(C), jnp.asarray(u), jnp.asarray(w), block_n=block_n
        )
        np.testing.assert_allclose(got, ref.rank1_update_ref(C, u, w),
                                   rtol=1e-12)

    @pytest.mark.parametrize("dtype", [np.float32, np.float64])
    def test_dtypes(self, dtype):
        rng = np.random.default_rng(1)
        C = rng.normal(size=(9, 5)).astype(dtype)
        u = rng.normal(size=9).astype(dtype)
        w = rng.normal(size=5).astype(dtype)
        got = rank1_update(jnp.asarray(C), jnp.asarray(u), jnp.asarray(w))
        assert np.asarray(got).dtype == dtype
        tol = 1e-6 if dtype == np.float32 else 1e-12
        np.testing.assert_allclose(got, ref.rank1_update_ref(C, u, w),
                                   rtol=tol, atol=tol)

    def test_zero_u_is_identity(self):
        rng = np.random.default_rng(2)
        C = rng.normal(size=(6, 6))
        got = rank1_update(
            jnp.asarray(C), jnp.zeros(6), jnp.asarray(rng.normal(size=6))
        )
        np.testing.assert_array_equal(np.asarray(got), C)


class TestSMWIdentities:
    """The cache updates must track the explicitly re-inverted G.

    After committing features S in any order:
        G  = (X_S^T X_S + lam I)^{-1}
        C == G X^T,  a == G y,  d == diag(G)
    """

    @settings(**SETTINGS)
    @given(
        n=st.integers(3, 14),
        m=st.integers(3, 14),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_caches_equal_explicit_inverse(self, n, m, seed):
        rng = np.random.default_rng(seed)
        lam = float(10 ** rng.uniform(-1, 1))
        X = rng.normal(size=(n, m))
        y = np.where(rng.normal(size=m) > 0, 1.0, -1.0)
        C = X.T / lam
        a = y / lam
        d = np.full(m, 1.0 / lam)
        steps = min(3, n)
        chosen = rng.choice(n, size=steps, replace=False)
        for b in chosen:
            C, a, d = (np.asarray(t)
                       for t in ref.commit_ref(X, C, a, d, int(b)))
        Xs = X[list(chosen), :]
        G = np.linalg.inv(Xs.T @ Xs + lam * np.eye(m))
        np.testing.assert_allclose(C, G @ X.T, rtol=1e-8, atol=1e-8)
        np.testing.assert_allclose(a, G @ y, rtol=1e-8, atol=1e-8)
        np.testing.assert_allclose(d, np.diag(G), rtol=1e-8, atol=1e-8)
