"""Pallas score kernel vs the pure-jnp oracle and vs brute-force LOO.

This is the CORE Layer-1 correctness signal: hypothesis sweeps shapes,
dtypes, regularization strengths and cache states; every case must agree
with ref.loo_scores_ref, and a second family of tests checks the oracle
itself against literal leave-one-out retraining (no shortcuts at all).
"""

from __future__ import annotations

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402
from hypothesis import given, settings, strategies as st  # noqa: E402

from compile.kernels import loo_scores, ref  # noqa: E402
from .conftest import advanced_caches, ones, random_problem  # noqa: E402

SETTINGS = dict(max_examples=20, deadline=None)


def _run_both(X, y, C, a, d, cmask=None, emask=None, block_n=128):
    n, m = X.shape
    cmask = ones(n, X.dtype) if cmask is None else cmask
    emask = ones(m, X.dtype) if emask is None else emask
    got = loo_scores(
        jnp.asarray(X), jnp.asarray(C), jnp.asarray(a), jnp.asarray(d),
        jnp.asarray(y), jnp.asarray(cmask), jnp.asarray(emask),
        block_n=block_n,
    )
    want = ref.loo_scores_ref(
        jnp.asarray(X), jnp.asarray(C), jnp.asarray(a), jnp.asarray(d),
        jnp.asarray(y), jnp.asarray(cmask), jnp.asarray(emask),
    )
    return got, want


class TestKernelVsRef:
    @settings(**SETTINGS)
    @given(
        n=st.integers(2, 40),
        m=st.integers(2, 40),
        lam=st.floats(1e-3, 1e3),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_fresh_caches_match_ref(self, n, m, lam, seed):
        rng = np.random.default_rng(seed)
        X, y, C, a, d = random_problem(rng, n, m, lam)
        (g_sq, g_01), (w_sq, w_01) = _run_both(X, y, C, a, d)
        np.testing.assert_allclose(g_sq, w_sq, rtol=1e-10, atol=1e-10)
        np.testing.assert_allclose(g_01, w_01, rtol=0, atol=0)

    @settings(**SETTINGS)
    @given(
        n=st.integers(4, 24),
        m=st.integers(4, 24),
        steps=st.integers(1, 3),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_advanced_caches_match_ref(self, n, m, steps, seed):
        rng = np.random.default_rng(seed)
        lam = float(10 ** rng.uniform(-2, 2))
        X, y, C, a, d, _ = advanced_caches(rng, n, m, lam, steps)
        (g_sq, g_01), (w_sq, w_01) = _run_both(X, y, C, a, d)
        np.testing.assert_allclose(g_sq, w_sq, rtol=1e-9, atol=1e-9)
        np.testing.assert_allclose(g_01, w_01, rtol=0, atol=0)

    @pytest.mark.parametrize("dtype", [np.float32, np.float64])
    def test_dtypes(self, dtype):
        rng = np.random.default_rng(7)
        X, y, C, a, d = random_problem(rng, 12, 9, 2.0, dtype=dtype)
        (g_sq, g_01), (w_sq, w_01) = _run_both(X, y, C, a, d)
        tol = 1e-4 if dtype == np.float32 else 1e-10
        assert np.asarray(g_sq).dtype == dtype
        np.testing.assert_allclose(g_sq, w_sq, rtol=tol, atol=tol)
        np.testing.assert_allclose(g_01, w_01, rtol=0, atol=0)

    @pytest.mark.parametrize("block_n", [1, 2, 4, 8, 16, 128])
    def test_block_sizes(self, block_n):
        """Blocking over candidates must not change any score."""
        rng = np.random.default_rng(3)
        X, y, C, a, d = random_problem(rng, 16, 11, 0.5)
        (g_sq, _), (w_sq, _) = _run_both(X, y, C, a, d, block_n=block_n)
        np.testing.assert_allclose(g_sq, w_sq, rtol=1e-10, atol=1e-10)

    def test_candidate_mask_scores_big(self):
        rng = np.random.default_rng(11)
        X, y, C, a, d = random_problem(rng, 10, 8, 1.0)
        cmask = ones(10)
        cmask[[2, 5]] = 0.0
        (g_sq, g_01), _ = _run_both(X, y, C, a, d, cmask=cmask)
        g_sq = np.asarray(g_sq)
        g_01 = np.asarray(g_01)
        assert (g_sq[[2, 5]] >= ref.BIG).all()
        assert (g_01[[2, 5]] >= ref.BIG).all()
        assert (g_sq[[0, 1, 3, 4, 6, 7, 8, 9]] < ref.BIG).all()


class TestKernelVsBruteForce:
    """The kernel's score must equal literal LOO retraining (Algorithm 1)."""

    @settings(**SETTINGS)
    @given(
        n=st.integers(2, 10),
        m=st.integers(3, 14),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_fresh_cache_scores_equal_brute_force(self, n, m, seed):
        rng = np.random.default_rng(seed)
        lam = float(10 ** rng.uniform(-1, 1))
        X, y, C, a, d = random_problem(rng, n, m, lam)
        (g_sq, _), _ = _run_both(X, y, C, a, d)
        g_sq = np.asarray(g_sq)
        for i in range(n):
            p = ref.brute_force_loo_np(X[[i], :], y, lam)
            want = float(np.sum((y - p) ** 2))
            assert g_sq[i] == pytest.approx(want, rel=1e-6)

    def test_advanced_cache_scores_equal_brute_force(self):
        rng = np.random.default_rng(5)
        n, m, lam = 8, 12, 0.8
        X, y, C, a, d, chosen = advanced_caches(rng, n, m, lam, steps=2)
        (g_sq, _), _ = _run_both(X, y, C, a, d)
        g_sq = np.asarray(g_sq)
        for i in range(n):
            if i in chosen:
                continue
            S = chosen + [i]
            p = ref.brute_force_loo_np(X[S, :], y, lam)
            want = float(np.sum((y - p) ** 2))
            assert g_sq[i] == pytest.approx(want, rel=1e-6), f"cand {i}"


class TestPadding:
    """DESIGN.md §5: padding examples/features with zeros is exact."""

    @settings(**SETTINGS)
    @given(
        n=st.integers(2, 12),
        m=st.integers(2, 12),
        pad_n=st.integers(0, 8),
        pad_m=st.integers(0, 8),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_pad_invariance(self, n, m, pad_n, pad_m, seed):
        rng = np.random.default_rng(seed)
        lam = 1.3
        X, y, C, a, d = random_problem(rng, n, m, lam)
        (g_sq, g_01), _ = _run_both(X, y, C, a, d)

        Np, Mp = n + pad_n, m + pad_m
        Xp = np.zeros((Np, Mp))
        Xp[:n, :m] = X
        yp = np.zeros(Mp)
        yp[:m] = y
        Cp = Xp.T / lam
        ap = yp / lam
        dp = np.full(Mp, 1.0 / lam)
        cmask = np.zeros(Np)
        cmask[:n] = 1.0
        emask = np.zeros(Mp)
        emask[:m] = 1.0
        (p_sq, p_01), _ = _run_both(Xp, yp, Cp, ap, dp, cmask=cmask,
                                    emask=emask)
        np.testing.assert_allclose(
            np.asarray(p_sq)[:n], np.asarray(g_sq), rtol=1e-10, atol=1e-12
        )
        np.testing.assert_allclose(
            np.asarray(p_01)[:n], np.asarray(g_01), rtol=0, atol=0
        )
        assert (np.asarray(p_sq)[n:] >= ref.BIG).all()

    def test_example_mask_drops_loss_contribution(self):
        rng = np.random.default_rng(2)
        X, y, C, a, d = random_problem(rng, 6, 10, 1.0)
        emask = ones(10)
        emask[3] = 0.0
        (g_sq, _), (w_sq, _) = _run_both(X, y, C, a, d, emask=emask)
        np.testing.assert_allclose(g_sq, w_sq, rtol=1e-10)
        # and it differs from the unmasked scores
        (f_sq, _), _ = _run_both(X, y, C, a, d)
        assert not np.allclose(np.asarray(g_sq), np.asarray(f_sq))


class TestZeroOneLoss:
    def test_zero_prediction_counts_as_error(self):
        # Construct caches so some LOO prediction is exactly 0: use the
        # analytic identity on a tiny hand-made case instead; simplest is
        # to verify the convention through the ref path on crafted P.
        y = np.array([1.0, -1.0])
        P = np.array([0.0, -0.5])
        wrong = np.where((y * P) > 0, 0.0, 1.0)
        assert wrong.tolist() == [1.0, 0.0]

    def test_01_loss_counts_misclassifications(self):
        rng = np.random.default_rng(9)
        n, m, lam = 5, 20, 1.0
        X, y, C, a, d = random_problem(rng, n, m, lam)
        (_, g_01), _ = _run_both(X, y, C, a, d)
        g_01 = np.asarray(g_01)
        for i in range(n):
            p = ref.brute_force_loo_np(X[[i], :], y, lam)
            want = float(np.sum(y * p <= 0))
            assert g_01[i] == pytest.approx(want)
