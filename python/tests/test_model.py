"""Layer-2 entry points: shapes, semantics, and full-loop equivalence.

The decisive test drives a complete greedy selection using only the AOT
entry points (init_state / score_step / commit_step), exactly as the Rust
coordinator will, and requires the selected sequence and final weights to
match the verbatim-Algorithm-3 numpy reference.
"""

from __future__ import annotations

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402
from hypothesis import given, settings, strategies as st  # noqa: E402

from compile import model  # noqa: E402
from compile.kernels import ref  # noqa: E402

SETTINGS = dict(max_examples=15, deadline=None)


def drive_selection(X, y, lam, k, classification=True):
    """Run greedy RLS through the L2 entry points (the L3 control flow)."""
    n, m = X.shape
    C, a, d = model.init_state(
        jnp.asarray(X), jnp.asarray(y), jnp.asarray([lam])
    )
    cmask = np.ones(n)
    emask = np.ones(m)
    selected = []
    for _ in range(k):
        e_sq, e_01 = model.score_step(
            jnp.asarray(X), C, a, d, jnp.asarray(y),
            jnp.asarray(cmask), jnp.asarray(emask),
        )
        scores = np.asarray(e_01 if classification else e_sq)
        b = int(np.argmin(scores))
        C, a, d = model.commit_step(
            jnp.asarray(X), C, a, d, jnp.asarray(b, dtype=jnp.int32)
        )
        cmask[b] = 0.0
        selected.append(b)
    w = np.zeros(n)
    w[selected] = X[selected, :] @ np.asarray(a)
    return selected, w


class TestInitState:
    def test_values(self):
        rng = np.random.default_rng(0)
        X = rng.normal(size=(5, 7))
        y = rng.normal(size=7)
        C, a, d = model.init_state(
            jnp.asarray(X), jnp.asarray(y), jnp.asarray([2.0])
        )
        np.testing.assert_allclose(C, X.T / 2.0)
        np.testing.assert_allclose(a, y / 2.0)
        np.testing.assert_allclose(d, np.full(7, 0.5))


class TestCommitStep:
    @settings(**SETTINGS)
    @given(
        n=st.integers(2, 20),
        m=st.integers(2, 20),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, n, m, seed):
        rng = np.random.default_rng(seed)
        lam = 1.0
        X = rng.normal(size=(n, m))
        y = rng.normal(size=m)
        C = X.T / lam
        a = y / lam
        d = np.full(m, 1.0 / lam)
        b = int(rng.integers(n))
        C2, a2, d2 = model.commit_step(
            jnp.asarray(X), jnp.asarray(C), jnp.asarray(a), jnp.asarray(d),
            jnp.asarray(b, dtype=jnp.int32),
        )
        Cr, ar, dr = ref.commit_ref(X, C, a, d, b)
        np.testing.assert_allclose(C2, Cr, rtol=1e-10, atol=1e-10)
        np.testing.assert_allclose(a2, ar, rtol=1e-10, atol=1e-10)
        np.testing.assert_allclose(d2, dr, rtol=1e-10, atol=1e-10)


class TestFullSelectionLoop:
    @settings(**SETTINGS)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_equivalent_to_reference_regression(self, seed):
        rng = np.random.default_rng(seed)
        n, m, k, lam = 12, 15, 4, 1.0
        X = rng.normal(size=(n, m))
        y = rng.normal(size=m)
        sel, w = drive_selection(X, y, lam, k, classification=False)
        sel_ref, w_ref = ref.greedy_rls_np(X, y, lam, k, classification=False)
        assert sel == sel_ref
        np.testing.assert_allclose(w, w_ref, rtol=1e-8, atol=1e-8)

    def test_equivalent_to_reference_classification(self):
        rng = np.random.default_rng(42)
        n, m, k, lam = 10, 30, 5, 0.5
        X = rng.normal(size=(n, m))
        y = np.where(rng.normal(size=m) > 0, 1.0, -1.0)
        # plant two informative features so ties are unlikely
        X[0] += y * 1.5
        X[3] += y * 1.0
        sel, w = drive_selection(X, y, lam, k, classification=True)
        sel_ref, w_ref = ref.greedy_rls_np(X, y, lam, k, classification=True)
        assert sel == sel_ref
        np.testing.assert_allclose(w, w_ref, rtol=1e-8, atol=1e-8)
        assert 0 in sel[:2]  # the planted feature is found early

    def test_selected_equals_wrapper_bruteforce(self):
        """Greedy RLS == Algorithm 1 (retrain per fold, per candidate)."""
        rng = np.random.default_rng(1)
        n, m, k, lam = 6, 9, 3, 0.7
        X = rng.normal(size=(n, m))
        y = rng.normal(size=m)
        sel, _ = drive_selection(X, y, lam, k, classification=False)
        S = []
        for _ in range(k):
            best, best_e = -1, np.inf
            for i in range(n):
                if i in S:
                    continue
                p = ref.brute_force_loo_np(X[S + [i], :], y, lam)
                e = float(np.sum((y - p) ** 2))
                if e < best_e:
                    best_e, best = e, i
            S.append(best)
        assert sel == S


class TestPredictAndTrainDual:
    def test_predict(self):
        rng = np.random.default_rng(3)
        w = rng.normal(size=8)
        Xt = rng.normal(size=(8, 5))
        got = model.predict(jnp.asarray(w), jnp.asarray(Xt))
        np.testing.assert_allclose(got, w @ Xt, rtol=1e-12)

    def test_predict_zero_padding_rows_are_inert(self):
        rng = np.random.default_rng(4)
        w = np.zeros(8)
        w[:3] = rng.normal(size=3)
        Xt = np.zeros((8, 5))
        Xt[:3] = rng.normal(size=(3, 5))
        got = model.predict(jnp.asarray(w), jnp.asarray(Xt))
        np.testing.assert_allclose(got, w[:3] @ Xt[:3], rtol=1e-12)

    def test_train_dual_matches_numpy(self):
        rng = np.random.default_rng(5)
        k, m, lam = 4, 12, 0.9
        Xs = rng.normal(size=(k, m))
        y = rng.normal(size=m)
        w, a = model.train_dual(
            jnp.asarray(Xs), jnp.asarray(y), jnp.asarray([lam])
        )
        a_np, _ = ref.rls_dual_train_np(Xs, y, lam)
        np.testing.assert_allclose(a, a_np, rtol=1e-9, atol=1e-9)
        np.testing.assert_allclose(w, Xs @ a_np, rtol=1e-9, atol=1e-9)

    def test_train_dual_equals_primal(self):
        """eq. (3) == eq. (4)."""
        rng = np.random.default_rng(6)
        k, m, lam = 5, 9, 1.7
        Xs = rng.normal(size=(k, m))
        y = rng.normal(size=m)
        w_dual, _ = model.train_dual(
            jnp.asarray(Xs), jnp.asarray(y), jnp.asarray([lam])
        )
        w_primal = np.linalg.solve(Xs @ Xs.T + lam * np.eye(k), Xs @ y)
        np.testing.assert_allclose(w_dual, w_primal, rtol=1e-9, atol=1e-9)
