"""The pure-HLO CG solve inside train_dual (AOT-compatible replacement for
jnp.linalg.solve, whose LAPACK TYPED_FFI custom-call xla_extension 0.5.1
cannot compile). These tests pin its accuracy across conditioning regimes
and bucket-style zero padding."""

from __future__ import annotations

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
import pytest  # noqa: E402
from hypothesis import given, settings, strategies as st  # noqa: E402

from compile import model  # noqa: E402

SETTINGS = dict(max_examples=15, deadline=None)


def direct_dual(Xs, y, lam):
    m = Xs.shape[1]
    return np.linalg.solve(Xs.T @ Xs + lam * np.eye(m), y)


class TestCgTrainDual:
    @settings(**SETTINGS)
    @given(
        k=st.integers(1, 10),
        m=st.integers(2, 24),
        lam_exp=st.floats(-2, 3),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_direct_solve(self, k, m, lam_exp, seed):
        rng = np.random.default_rng(seed)
        Xs = rng.normal(size=(k, m))
        y = rng.normal(size=m)
        lam = 10.0**lam_exp
        w, a = model.train_dual(
            jnp.asarray(Xs), jnp.asarray(y), jnp.asarray([lam])
        )
        a_np = direct_dual(Xs, y, lam)
        np.testing.assert_allclose(a, a_np, rtol=1e-7, atol=1e-9)
        np.testing.assert_allclose(w, Xs @ a_np, rtol=1e-7, atol=1e-9)

    def test_small_lambda_hard_case(self):
        # lam = 1e-4 with k < m: K + lam I has k large eigenvalues and
        # m−k tiny ones — the stress case for CG iteration counts
        rng = np.random.default_rng(0)
        k, m, lam = 6, 40, 1e-4
        Xs = rng.normal(size=(k, m))
        y = rng.normal(size=m)
        w, a = model.train_dual(
            jnp.asarray(Xs), jnp.asarray(y), jnp.asarray([lam])
        )
        a_np = direct_dual(Xs, y, lam)
        np.testing.assert_allclose(a, a_np, rtol=1e-5, atol=1e-7)

    def test_zero_padding_rows_leave_real_solution_intact(self):
        # bucket-style padding: extra all-zero feature rows and zero-
        # labelled examples must not perturb the real coordinates
        rng = np.random.default_rng(1)
        k, m, kp, mp, lam = 4, 10, 7, 16, 0.8
        Xs = rng.normal(size=(k, m))
        y = rng.normal(size=m)
        Xp = np.zeros((kp, mp))
        Xp[:k, :m] = Xs
        yp = np.zeros(mp)
        yp[:m] = y
        w, a = model.train_dual(
            jnp.asarray(Xp), jnp.asarray(yp), jnp.asarray([lam])
        )
        a_np = direct_dual(Xs, y, lam)
        np.testing.assert_allclose(np.asarray(a)[:m], a_np, rtol=1e-7,
                                   atol=1e-9)
        np.testing.assert_allclose(np.asarray(w)[:k], Xs @ a_np, rtol=1e-7,
                                   atol=1e-9)
        assert np.all(np.asarray(w)[k:] == 0.0)

    def test_zero_rhs_gives_zero_solution(self):
        rng = np.random.default_rng(2)
        Xs = rng.normal(size=(3, 8))
        w, a = model.train_dual(
            jnp.asarray(Xs), jnp.zeros(8), jnp.asarray([1.0])
        )
        np.testing.assert_array_equal(np.asarray(a), np.zeros(8))
        np.testing.assert_array_equal(np.asarray(w), np.zeros(3))

    @pytest.mark.parametrize("lam", [1e-3, 1.0, 1e3])
    def test_residual_is_small(self, lam):
        rng = np.random.default_rng(3)
        Xs = rng.normal(size=(5, 20))
        y = rng.normal(size=20)
        _, a = model.train_dual(
            jnp.asarray(Xs), jnp.asarray(y), jnp.asarray([lam])
        )
        a = np.asarray(a)
        resid = Xs.T @ (Xs @ a) + lam * a - y
        assert np.linalg.norm(resid) < 1e-7 * max(1.0, np.linalg.norm(y))
