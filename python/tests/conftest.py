"""Shared fixtures/helpers for the Layer-1/2 test suite."""

from __future__ import annotations

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402


def random_problem(rng, n, m, lam=1.0, classification=True, dtype=np.float64):
    """A random greedy-RLS problem instance with fresh caches."""
    X = rng.normal(size=(n, m)).astype(dtype)
    if classification:
        y = np.where(rng.normal(size=m) > 0, 1.0, -1.0).astype(dtype)
    else:
        y = rng.normal(size=m).astype(dtype)
    C = (X.T / lam).astype(dtype)
    a = (y / lam).astype(dtype)
    d = np.full(m, 1.0 / lam, dtype=dtype)
    return X, y, C, a, d


def advanced_caches(rng, n, m, lam, steps, dtype=np.float64):
    """Caches after `steps` random commits — exercises non-initial states."""
    from compile.kernels import ref

    X, y, C, a, d = random_problem(rng, n, m, lam, dtype=dtype)
    chosen = rng.choice(n, size=steps, replace=False)
    for b in chosen:
        C, a, d = (np.asarray(t) for t in ref.commit_ref(X, C, a, d, int(b)))
    return X, y, C.astype(dtype), a.astype(dtype), d.astype(dtype), list(chosen)


def ones(m, dtype=np.float64):
    return np.ones(m, dtype=dtype)
