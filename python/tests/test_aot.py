"""AOT lowering sanity: HLO text artifacts are well-formed and complete.

These tests protect the Rust runtime's assumptions: text format (parseable
header), tuple outputs, no Mosaic custom-calls (interpret=True honored),
all manifest entries present, and bucket divisibility by the kernels'
block sizes.
"""

from __future__ import annotations

import os

import pytest

from compile import aot, model


def test_bucket_shapes_divisible_by_block():
    for m, n in aot.BUCKETS:
        assert n % 128 == 0 or n < 128, (m, n)
        assert m >= 2 and n >= 2


@pytest.mark.parametrize("entry", aot.SELECTION_ENTRIES)
def test_lowering_produces_hlo_text(entry):
    text = aot.lower_entry(entry, 64, 128)
    assert text.startswith("HloModule"), text[:80]
    assert "ENTRY" in text
    # interpret=True must mean no Mosaic/TPU custom calls in the HLO.
    assert "tpu_custom_call" not in text
    assert "mosaic" not in text.lower()


def test_score_step_hlo_has_both_outputs():
    text = aot.lower_entry("score_step", 64, 128)
    # return_tuple=True: root is a 2-tuple of f64[128] score vectors.
    assert "(f64[128]" in text.replace(" ", "")[:20000] or \
        "tuple" in text


def test_score_step_hlo_has_no_mxm_intermediate():
    """The paper's memory claim: G (m x m) is never materialized.

    At bucket (m=256, n=256) an f64[256,256] temporary would be allowed
    (same as C), so lower an asymmetric bucket (m=64, n=128) and assert no
    f64[64,64] shape appears: any m-by-m intermediate would betray a G
    materialization.
    """
    text = aot.lower_entry("score_step", 64, 128)
    assert "f64[64,64]" not in text


def test_example_args_signature_errors():
    with pytest.raises(ValueError):
        model.example_args("nope", 4, 4)


def test_artifacts_dir_complete():
    """If `make artifacts` has run, every manifest row exists on disk."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    manifest = os.path.join(art, "manifest.tsv")
    if not os.path.exists(manifest):
        pytest.skip("artifacts not built")
    with open(manifest) as fh:
        rows = [ln.split("\t") for ln in fh if not ln.startswith("#")]
    assert rows, "empty manifest"
    for row in rows:
        path = os.path.join(art, row[1])
        assert os.path.exists(path), path
        with open(path) as fh:
            head = fh.read(64)
        assert head.startswith("HloModule"), path


def test_artifact_entry_coverage():
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    manifest = os.path.join(art, "manifest.tsv")
    if not os.path.exists(manifest):
        pytest.skip("artifacts not built")
    with open(manifest) as fh:
        entries = {ln.split("\t")[0] for ln in fh if not ln.startswith("#")}
    for e in ["init_state", "score_step", "commit_step", "predict",
              "train_dual"]:
        assert e in entries, f"missing artifacts for {e}"
