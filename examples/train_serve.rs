//! Train-and-serve-in-one-process demo: the streaming serve pipeline.
//!
//! ```sh
//! cargo run --release --offline --example train_serve
//! ```
//!
//! The paper's point is that greedy RLS is fast enough to train *while
//! you wait* — so the natural production shape is to serve while it
//! trains. Here a selection session publishes every committed round onto
//! the in-process [`ModelBus`]; a hot-swap server picks each version up
//! the instant it commits and worker threads answer query batches
//! against it concurrently, with no filesystem on the path. After the
//! session stops, one final pass is served entirely by the finished
//! model.
//!
//! [`ModelBus`]: greedy_rls::coordinator::stream::ModelBus

use greedy_rls::coordinator::stream::{self, TrainServeOptions};
use greedy_rls::data::synthetic::planted_sparse;
use greedy_rls::metrics::{accuracy, Loss};
use greedy_rls::select::{greedy::GreedyRls, SelectionConfig, SessionSelector};

fn main() -> anyhow::Result<()> {
    // 2000 examples, 300 features, 12 informative: enough rounds that
    // several versions serve real traffic before selection finishes.
    let ds = planted_sparse("train-serve", 2000, 300, 12, 1.0, 0.9, 0.05, 42);
    let cfg = SelectionConfig::builder()
        .k(20)
        .lambda(1.0)
        .loss(Loss::ZeroOne)
        .plateau(3, 1e-3)
        .build();
    println!(
        "training m={} n={} (k≤{}, plateau stop) while serving on 4 workers",
        ds.n_examples(),
        ds.n_features(),
        cfg.k
    );

    let session = GreedyRls.begin(&ds.x, &ds.y, &cfg)?;
    let opts = TrainServeOptions { workers: 4, batch: 128, queue_depth: 0 };
    let report = stream::train_serve(
        session,
        &mut greedy_rls::select::NoopObserver,
        None, // add an Autosaver here to compose with durable checkpoints
        &ds.x,
        &opts,
    )?;

    println!(
        "\nselected {} features; {} versions published, {} hot swaps, \
         {} batches answered mid-training",
        report.result.selected.len(),
        report.published,
        report.swaps,
        report.live_batches
    );
    println!("\nversion  rounds  batches   p50 µs   p99 µs");
    for v in &report.version_stats {
        println!(
            "{:>7}  {:>6}  {:>7}  {:>7.1}  {:>7.1}",
            v.version,
            v.rounds,
            v.batches,
            v.p50_s * 1e6,
            v.p99_s * 1e6
        );
    }
    let acc = accuracy(&ds.y, &report.final_preds);
    println!(
        "\nfinal pass (finished model): accuracy {acc:.3}, \
         p50 {:.1}µs, {:.0} ex/s",
        report.final_serve.p50_batch_s * 1e6,
        report.final_serve.throughput
    );
    println!(
        "(the same pipeline is `greedy-rls train-serve`; add \
         --checkpoint-dir for kill-safe runs — a version reaches the bus \
         only after its checkpoint is durable)"
    );
    Ok(())
}
