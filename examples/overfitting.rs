//! LOO-overfitting experiment (paper §4.3, Figures 10–15).
//!
//! ```sh
//! cargo run --release --offline --example overfitting
//! ```
//!
//! Compares the LOO accuracy estimate (the quantity the selection
//! maximizes) against held-out test accuracy, per number of selected
//! features. The paper's finding, reproduced on the stand-ins: the two
//! track closely on large-m datasets but LOO is over-optimistic on
//! small-m/large-n data (colon-cancer: m=62, n=2000), where the selection
//! can overfit its own criterion.

use greedy_rls::coordinator::cv;
use greedy_rls::data::registry;

fn main() -> anyhow::Result<()> {
    for (fig, name) in [
        ("10", "adult"),
        ("11", "australian"),
        ("12", "colon-cancer"),
        ("13", "german.numer"),
        ("14", "ijcnn1"),
        ("15", "mnist5"),
    ] {
        let ds = registry::load(name, false, 42)?;
        let k_max = ds.n_features().min(40);
        let folds = if ds.n_examples() < 100 { 5 } else { 10 };
        let curves = cv::run_cv(&ds, folds, k_max, 42)?;
        println!(
            "\n# Figure {fig}: {name} (m={}, n={}) — test vs LOO accuracy",
            ds.n_examples(),
            ds.n_features()
        );
        println!("k\ttest_acc\tloo_acc\tgap");
        let mut max_gap = 0.0_f64;
        for (i, k) in curves.ks.iter().enumerate() {
            let gap = curves.greedy_loo[i] - curves.greedy_test[i];
            max_gap = max_gap.max(gap);
            println!(
                "{k}\t{:.4}\t{:.4}\t{:+.4}",
                curves.greedy_test[i], curves.greedy_loo[i], gap
            );
        }
        println!(
            "# max LOO-optimism gap: {max_gap:+.3} {}",
            if max_gap > 0.08 {
                "(overfitting the LOO criterion — paper's small-m/large-n case)"
            } else {
                "(LOO tracks test closely — paper's large-m case)"
            }
        );
    }
    Ok(())
}
