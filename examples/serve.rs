//! Serving demo: train a sparse model, persist it, serve batched requests
//! on both execution paths, and report latency percentiles.
//!
//! ```sh
//! make artifacts && cargo run --release --offline --example serve
//! ```
//!
//! The deployment story the paper motivates ("limited memory and
//! real-time response demands"): a k-sparse linear predictor is O(k) per
//! request and a few hundred bytes of state.

use greedy_rls::coordinator::{self, serve, EngineKind};
use greedy_rls::data::registry;
use greedy_rls::metrics::{accuracy, Loss};
use greedy_rls::runtime::Runtime;
use greedy_rls::select::SelectionConfig;

fn main() -> anyhow::Result<()> {
    let mut ds = registry::load("ijcnn1", false, 42)?;
    ds.standardize();
    let cfg = SelectionConfig { k: 10, lambda: 1.0, loss: Loss::ZeroOne, ..Default::default() };
    println!(
        "training sparse model: {} (m={}, n={}), k={}",
        ds.name,
        ds.n_examples(),
        ds.n_features(),
        cfg.k
    );
    let model = coordinator::fit(EngineKind::Native, None, &ds, &cfg)?;
    println!("selected features: {:?}", model.selected);
    println!(
        "model size: {} weights = {} bytes as text",
        model.weights.len(),
        coordinator::model_to_string(&model).len()
    );

    for batch in [1usize, 16, 128] {
        let (preds, st) = serve::serve_native(&model, &ds.x, batch)?;
        let acc = accuracy(&ds.y, &preds);
        println!(
            "native  batch={batch:>4}: p50 {:>9.2}µs  p99 {:>9.2}µs  \
             {:>10.0} ex/s  acc {acc:.3}",
            st.p50_batch_s * 1e6,
            st.p99_batch_s * 1e6,
            st.throughput
        );
    }

    match Runtime::open("artifacts") {
        Ok(rt) => {
            for batch in [16usize, 128] {
                let (preds, st) = serve::serve_pjrt(&rt, &model, &ds.x, batch)?;
                let acc = accuracy(&ds.y, &preds);
                println!(
                    "pjrt    batch={batch:>4}: p50 {:>9.2}µs  p99 {:>9.2}µs  \
                     {:>10.0} ex/s  acc {acc:.3}",
                    st.p50_batch_s * 1e6,
                    st.p99_batch_s * 1e6,
                    st.throughput
                );
            }
            println!(
                "\n(native wins for k-sparse dot products, as expected — the \
                 PJRT path exists to prove the artifact pipeline serves too)"
            );
        }
        Err(e) => println!("skipping PJRT path ({e}); run `make artifacts`"),
    }
    Ok(())
}
