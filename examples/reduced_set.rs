//! Reduced-set (center) selection for kernel RLS — the paper's §5
//! future-work direction, implemented by running greedy RLS over kernel
//! columns (see `select::centers`).
//!
//! ```sh
//! cargo run --release --offline --example reduced_set
//! ```
//!
//! Workload: a radially separable "ring" problem that defeats any linear
//! model. Full RBF-kernel RLS solves it with m dual coefficients; greedy
//! center selection recovers the same accuracy with a handful of centers,
//! shrinking the model (and per-prediction cost) by an order of magnitude.

use greedy_rls::data::Dataset;
use greedy_rls::linalg::Matrix;
use greedy_rls::metrics::{accuracy, Loss};
use greedy_rls::rls::kernel::{Kernel, KernelRls};
use greedy_rls::rng::Pcg64;
use greedy_rls::select::{
    centers::CenterSelector, greedy::GreedyRls, SelectionConfig, Selector,
};

fn ring(m: usize, seed: u64) -> Dataset {
    let mut rng = Pcg64::new(seed, 501);
    let mut x = Matrix::zeros(2, m);
    let mut y = vec![0.0; m];
    for j in 0..m {
        let (a, b) = (rng.normal(), rng.normal());
        x[(0, j)] = a;
        x[(1, j)] = b;
        y[j] = if (a * a + b * b).sqrt() > 1.1 { 1.0 } else { -1.0 };
    }
    Dataset::new("ring", x, y)
}

fn main() -> anyhow::Result<()> {
    let train = ring(300, 1);
    let test = ring(300, 2);
    let kernel = Kernel::Rbf { gamma: 1.0 };
    let lambda = 0.5;

    // baseline 1: best k *linear* features (hopeless on a ring)
    let cfg2 = SelectionConfig { k: 2, lambda, loss: Loss::ZeroOne, ..Default::default() };
    let lin = GreedyRls.select(&train.x, &train.y, &cfg2)?;
    let acc_lin =
        accuracy(&test.y, &lin.predictor().predict_matrix(&test.x));
    println!("linear greedy RLS (k=2 of 2 features):  test acc {acc_lin:.3}");

    // baseline 2: full kernel RLS — m = 300 dual coefficients
    let full = KernelRls::fit(&train.x, &train.y, kernel, lambda);
    let acc_full = accuracy(&test.y, &full.predict(&test.x));
    println!(
        "full kernel RLS ({} centers):           test acc {acc_full:.3}",
        train.n_examples()
    );

    // greedy center selection: grow the expansion one center at a time
    println!("\ngreedy center selection (LOO criterion over kernel columns):");
    println!("k_centers  test_acc  model_coeffs");
    for k in [2usize, 4, 8, 16, 32] {
        let cfg = SelectionConfig { k, lambda, loss: Loss::ZeroOne, ..Default::default() };
        let (model, _) =
            CenterSelector { kernel }.fit(&train.x, &train.y, &cfg)?;
        let acc = accuracy(&test.y, &model.predict(&test.x));
        println!("{k:>9}  {acc:>8.3}  {:>12}", model.weights.len());
    }
    println!(
        "\n→ a few dozen selected centers ≈ the {}-coefficient full model,\n  \
         exactly the reduced-set payoff §5 of the paper anticipates",
        train.n_examples()
    );
    Ok(())
}
