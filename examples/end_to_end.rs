//! End-to-end driver: every layer of the system on a real small workload.
//!
//! ```sh
//! make artifacts && cargo run --release --offline --example end_to_end
//! ```
//!
//! Proves the full stack composes (recorded in EXPERIMENTS.md):
//!
//! 1. **Data** — benchmark stand-ins with the paper's Table-1 shapes;
//! 2. **λ grid search** via the eq. 7/8 LOO shortcut on the training fold;
//! 3. **Selection** with the paper's O(kmn) greedy RLS on the **native**
//!    engine AND through the **PJRT artifacts** (Pallas score kernel +
//!    rank-1 update compiled from HLO text) — results must agree exactly;
//! 4. **Quality** — greedy vs random test accuracy (the Fig-4..9 claim);
//! 5. **Scaling** — measured runtime vs m showing the linear trend
//!    (the Fig-3 claim);
//! 6. **Serving** — the selected sparse model answers batched requests on
//!    both the native path and the PJRT `predict` artifact.

use greedy_rls::bench::time_once;
use greedy_rls::coordinator::{self, cv, grid, serve, EngineKind};
use greedy_rls::data::{registry, synthetic};
use greedy_rls::metrics::{accuracy, Loss};
use greedy_rls::rng::Pcg64;
use greedy_rls::runtime::Runtime;
use greedy_rls::select::{
    greedy::GreedyRls, random::RandomSelector, SelectionConfig, Selector,
};

fn main() -> anyhow::Result<()> {
    println!("=== greedy RLS end-to-end driver ===\n");

    // ---------------------------------------------------------------- 1
    let ds = registry::load("australian", false, 42)?;
    println!(
        "[1] dataset {}: m={} n={}",
        ds.name,
        ds.n_examples(),
        ds.n_features()
    );
    let mut rng = Pcg64::seeded(7);
    let (tr, te) = greedy_rls::data::folds::train_test_split(
        ds.n_examples(),
        0.25,
        &mut rng,
    );
    let mut train = ds.subset(&tr);
    let mut test = ds.subset(&te);
    let stats = train.standardize();
    test.apply_standardization(&stats);

    // ---------------------------------------------------------------- 2
    let (lambda, crit) = grid::search(
        &train.x,
        &train.y,
        &grid::default_grid(),
        Loss::ZeroOne,
    );
    println!(
        "[2] λ grid search (full-feature LOO): λ={lambda} \
         (LOO errors {crit:.0}/{})",
        train.n_examples()
    );

    // ---------------------------------------------------------------- 3
    let k = 8.min(train.n_features());
    let cfg = SelectionConfig { k, lambda, loss: Loss::ZeroOne, ..Default::default() };
    let native = GreedyRls.select(&train.x, &train.y, &cfg)?;
    println!("[3] native engine selected:  {:?}", native.selected);

    let rt = Runtime::open("artifacts")?;
    let pjrt = coordinator::select_with_engine(
        EngineKind::Pjrt,
        Some(&rt),
        &train.x,
        &train.y,
        &cfg,
    )?;
    println!("    PJRT engine selected:    {:?}", pjrt.selected);
    anyhow::ensure!(
        native.selected == pjrt.selected,
        "engine disagreement!"
    );
    let max_dw = native
        .weights
        .iter()
        .zip(&pjrt.weights)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0_f64, f64::max);
    println!("    engines agree; max |Δw| = {max_dw:.2e}");

    // ---------------------------------------------------------------- 4
    let p_greedy = native.predictor();
    let acc_greedy = accuracy(&test.y, &p_greedy.predict_matrix(&test.x));
    let rnd = RandomSelector { seed: 1 }.select(&train.x, &train.y, &cfg)?;
    let acc_rnd = accuracy(&test.y, &rnd.predictor().predict_matrix(&test.x));
    println!(
        "[4] test accuracy with k={k}: greedy {acc_greedy:.3} vs random \
         {acc_rnd:.3}"
    );

    // full 10-fold protocol on a second dataset (paper §4.2, one figure)
    let ds2 = registry::load("german.numer", false, 42)?;
    let curves = cv::run_cv(&ds2, 10, 12, 42)?;
    println!(
        "    german.numer 10-fold: k=12 greedy {:.3} random {:.3} \
         (LOO est. {:.3})",
        curves.greedy_test[11], curves.random_test[11], curves.greedy_loo[11]
    );

    // ---------------------------------------------------------------- 5
    println!("[5] runtime scaling (n=500, k=20, two-Gaussian data):");
    let mut last: Option<f64> = None;
    for m in [500usize, 1000, 2000, 4000] {
        let sds = synthetic::two_gaussians(m, 500, 25, 1.0, 3);
        let scfg = SelectionConfig {
            k: 20,
            lambda: 1.0,
            loss: Loss::ZeroOne,
            ..Default::default()
        };
        let secs = time_once(|| {
            GreedyRls.select(&sds.x, &sds.y, &scfg).unwrap();
        });
        let ratio = last.map(|p| secs / p).unwrap_or(f64::NAN);
        println!(
            "      m={m:>5}: {secs:>7.3}s{}",
            if ratio.is_nan() {
                String::new()
            } else {
                format!("  (×{ratio:.2} for ×2 data — linear ⇒ ≈2)")
            }
        );
        last = Some(secs);
    }

    // ---------------------------------------------------------------- 6
    let (pred_n, stats_n) = serve::serve_native(&p_greedy, &test.x, 32)?;
    let (pred_p, stats_p) = serve::serve_pjrt(&rt, &p_greedy, &test.x, 32)?;
    let agree = pred_n
        .iter()
        .zip(&pred_p)
        .all(|(a, b)| (a - b).abs() < 1e-9);
    println!(
        "[6] serving {} test examples (batch 32):",
        test.n_examples()
    );
    println!(
        "      native: p50 {:.2}µs/batch, {:.0} ex/s",
        stats_n.p50_batch_s * 1e6,
        stats_n.throughput
    );
    println!(
        "      pjrt:   p50 {:.2}µs/batch, {:.0} ex/s   (same predictions: {agree})",
        stats_p.p50_batch_s * 1e6,
        stats_p.throughput
    );
    anyhow::ensure!(agree, "serving paths disagree");
    let _ = &ds; // original dataset retained for future extensions

    // persist + reload the model as a deployment artifact
    let path = std::env::temp_dir().join("end_to_end_model.txt");
    coordinator::save_model(&p_greedy, &path)?;
    let reloaded = coordinator::load_model(&path)?;
    anyhow::ensure!(reloaded.selected == p_greedy.selected);
    println!("\nmodel persisted to {} and reloaded OK", path.display());
    println!("\n=== end-to-end: all layers compose ===");
    Ok(())
}
