//! Runtime-scaling experiment (paper §4.1, Figures 1–3).
//!
//! ```sh
//! cargo run --release --offline --example scaling [-- --full]
//! ```
//!
//! Greedy RLS vs the low-rank updated LS-SVM baseline on two-Gaussian
//! synthetic data with n=1000 features, selecting k=50 — the paper's exact
//! workload. The full paper grid (m to 50 000) takes a while on one vCPU;
//! the default is a reduced grid, `--full` runs the paper's.

use greedy_rls::bench::time_once;
use greedy_rls::data::synthetic::two_gaussians;
use greedy_rls::metrics::Loss;
use greedy_rls::select::{
    greedy::GreedyRls, lowrank::LowRankLsSvm, SelectionConfig, Selector,
};

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let (n, k) = (1000usize, 50usize);
    let cfg = SelectionConfig { k, lambda: 1.0, loss: Loss::ZeroOne, ..Default::default() };

    // Fig 1/2 workload: m = 500..5000, both methods.
    let ms_both: &[usize] = if full {
        &[500, 1000, 1500, 2000, 2500, 3000, 3500, 4000, 4500, 5000]
    } else {
        &[500, 1000, 1500, 2000]
    };
    println!("# Figures 1–2: greedy RLS vs low-rank updated LS-SVM");
    println!("# n={n} features, k={k} selected, two-Gaussian data");
    println!("m\tgreedy_s\tlowrank_s\tratio");
    for &m in ms_both {
        let ds = two_gaussians(m, n, 50, 1.0, 42);
        let t_g = time_once(|| {
            GreedyRls.select(&ds.x, &ds.y, &cfg).unwrap();
        });
        let t_l = time_once(|| {
            LowRankLsSvm.select(&ds.x, &ds.y, &cfg).unwrap();
        });
        println!("{m}\t{t_g:.3}\t{t_l:.3}\t{:.1}", t_l / t_g);
    }

    // Fig 3 workload: greedy only, larger m.
    let ms_large: &[usize] = if full {
        &[1000, 5000, 10000, 20000, 30000, 40000, 50000]
    } else {
        &[1000, 2000, 5000, 10000]
    };
    println!("\n# Figure 3: greedy RLS alone, larger training sets");
    println!("m\tgreedy_s\ts_per_km");
    for &m in ms_large {
        let ds = two_gaussians(m, n, 50, 1.0, 43);
        let t_g = time_once(|| {
            GreedyRls.select(&ds.x, &ds.y, &cfg).unwrap();
        });
        // seconds per (k·m·n/1e9): constant ⇒ linear scaling in m
        let unit = t_g / (k as f64 * m as f64 * n as f64 / 1e9);
        println!("{m}\t{t_g:.3}\t{unit:.3}");
    }
    println!("\n# constant s_per_km across rows ⇒ the paper's O(kmn) claim");
}
