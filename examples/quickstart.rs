//! Quickstart: select features with greedy RLS on synthetic data.
//!
//! ```sh
//! cargo run --release --offline --example quickstart
//! ```
//!
//! Demonstrates the minimal public-API path: generate a dataset, select k
//! features with the LOO criterion, inspect the criterion trajectory, and
//! evaluate the sparse model on held-out data.

use greedy_rls::coordinator::cv;
use greedy_rls::data::synthetic::planted_sparse;
use greedy_rls::metrics::Loss;
use greedy_rls::select::{greedy::GreedyRls, SelectionConfig, Selector};

fn main() -> anyhow::Result<()> {
    // 400 examples, 50 features of which 8 carry class signal.
    let ds = planted_sparse("quickstart", 400, 50, 8, 1.0, 0.9, 0.05, 42);
    println!(
        "dataset: m={} examples, n={} features (8 informative, planted)",
        ds.n_examples(),
        ds.n_features()
    );

    let cfg = SelectionConfig { k: 10, lambda: 1.0, loss: Loss::ZeroOne };
    let result = GreedyRls.select(&ds.x, &ds.y, &cfg)?;

    println!("\nselected features (in order): {:?}", result.selected);
    println!("round  feature  LOO errors (train)");
    for (i, round) in result.rounds.iter().enumerate() {
        println!(
            "{:>5}  {:>7}  {:>6.0} / {}",
            i + 1,
            round.feature,
            round.criterion,
            ds.n_examples()
        );
    }

    // Proper held-out evaluation of the same config.
    let (acc, _) = cv::holdout_accuracy(&ds, 0.25, &cfg, 7)?;
    println!("\nheld-out accuracy with {} features: {:.3}", cfg.k, acc);

    // Compare: all features, no selection (ridge on everything).
    let all: Vec<usize> = (0..ds.n_features()).collect();
    let xs = ds.x.select_rows(&all);
    let w = greedy_rls::rls::train(&xs, &ds.y, cfg.lambda);
    let p = greedy_rls::rls::Predictor { selected: all, weights: w };
    let full_acc =
        greedy_rls::metrics::accuracy(&ds.y, &p.predict_matrix(&ds.x));
    println!(
        "train accuracy with ALL {} features: {:.3}",
        ds.n_features(),
        full_acc
    );
    println!(
        "\n(the 10-feature model matches the paper's story: a small \
         LOO-selected subset ≈ the full model)"
    );
    Ok(())
}
