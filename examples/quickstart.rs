//! Quickstart: stepwise feature selection with greedy RLS.
//!
//! ```sh
//! cargo run --release --offline --example quickstart
//! ```
//!
//! Demonstrates the session API end to end: build a config with the
//! builder, `begin` a session, watch it select round by round, stop
//! early on the LOO plateau, and evaluate the sparse model — plus a
//! warm-started resume.

use greedy_rls::coordinator::cv;
use greedy_rls::data::synthetic::planted_sparse;
use greedy_rls::metrics::Loss;
use greedy_rls::select::{
    greedy::GreedyRls, SelectionConfig, SessionSelector, StepOutcome,
};

fn main() -> anyhow::Result<()> {
    // 400 examples, 50 features of which 8 carry class signal.
    let ds = planted_sparse("quickstart", 400, 50, 8, 1.0, 0.9, 0.05, 42);
    println!(
        "dataset: m={} examples, n={} features (8 informative, planted)",
        ds.n_examples(),
        ds.n_features()
    );

    // Early stopping in ~5 lines: ask for up to 25 features but stop once
    // the LOO criterion plateaus — the paper's Figs. 10–15 overfitting
    // guard.
    let cfg = SelectionConfig::builder()
        .k(25)
        .lambda(1.0)
        .loss(Loss::ZeroOne)
        .plateau(2, 1e-3)
        .build();
    let mut session = GreedyRls.begin(&ds.x, &ds.y, &cfg)?;
    println!("\nround  feature  LOO errors (train)");
    while let StepOutcome::Selected(round) = session.step()? {
        println!(
            "{:>5}  {:>7}  {:>6.0} / {}",
            session.rounds_done(),
            round.feature,
            round.criterion,
            ds.n_examples()
        );
    }
    let result = session.finish()?;
    println!(
        "stopped at {} of {} requested features ({})",
        result.selected.len(),
        cfg.k,
        result
            .rounds
            .last()
            .map(|r| format!("final LOO errors {:.0}", r.criterion))
            .unwrap_or_default()
    );
    println!("selected features (in order): {:?}", result.selected);

    // Warm start: resume from the first half of that run and drive to the
    // same stopping point — bit-identical to the uninterrupted session.
    let half = result.selected.len() / 2;
    let resumed = greedy_rls::select::run_to_completion(
        GreedyRls.begin_from(&ds.x, &ds.y, &cfg, &result.selected[..half])?,
    )?;
    println!(
        "warm start from {} features resumes to the same set: {}",
        half,
        resumed.selected == result.selected
    );

    // Proper held-out evaluation of the plateau-sized model.
    let eval_cfg = SelectionConfig::builder()
        .k(result.selected.len().max(1))
        .lambda(1.0)
        .loss(Loss::ZeroOne)
        .build();
    let (acc, _) = cv::holdout_accuracy(&ds, 0.25, &eval_cfg, 7)?;
    println!(
        "\nheld-out accuracy with {} features: {:.3}",
        eval_cfg.k, acc
    );

    // Compare: all features, no selection (ridge on everything).
    let all: Vec<usize> = (0..ds.n_features()).collect();
    let xs = ds.x.select_rows(&all);
    let w = greedy_rls::rls::train(&xs, &ds.y, cfg.lambda);
    let p = greedy_rls::rls::Predictor { selected: all, weights: w };
    let full_acc =
        greedy_rls::metrics::accuracy(&ds.y, &p.predict_matrix(&ds.x));
    println!(
        "train accuracy with ALL {} features: {:.3}",
        ds.n_features(),
        full_acc
    );
    println!(
        "\n(the plateau-stopped model matches the paper's story: a small \
         LOO-selected subset ≈ the full model, found without running to k)"
    );
    Ok(())
}
