//! Feature-quality experiment (paper §4.2, Figures 4–9).
//!
//! ```sh
//! cargo run --release --offline --example feature_quality [-- --datasets a,b]
//! ```
//!
//! Stratified 10-fold CV on each benchmark dataset: per fold, grid-search
//! λ by full-feature LOO, then select features greedily, plotting test
//! accuracy after every added feature for greedy vs the random baseline.

use greedy_rls::coordinator::cv;
use greedy_rls::data::registry;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let datasets: Vec<String> = args
        .iter()
        .position(|a| a == "--datasets")
        .and_then(|i| args.get(i + 1))
        .map(|v| v.split(',').map(|s| s.to_string()).collect())
        .unwrap_or_else(|| {
            registry::names().iter().map(|s| s.to_string()).collect()
        });

    for name in &datasets {
        let ds = registry::load(name, false, 42)?;
        let k_max = ds.n_features().min(40);
        println!(
            "\n# Figure {}: {name} (m={}, n={}), 10-fold stratified CV",
            match name.as_str() {
                "adult" => "4",
                "australian" => "5",
                "colon-cancer" => "6",
                "german.numer" => "7",
                "ijcnn1" => "8",
                "mnist5" => "9",
                _ => "-",
            },
            ds.n_examples(),
            ds.n_features()
        );
        let folds = if ds.n_examples() < 100 { 5 } else { 10 };
        let curves = cv::run_cv(&ds, folds, k_max, 42)?;
        println!("k\tgreedy_test\trandom_test\tstd");
        for (i, k) in curves.ks.iter().enumerate() {
            println!(
                "{k}\t{:.4}\t{:.4}\t{:.4}",
                curves.greedy_test[i],
                curves.random_test[i],
                curves.greedy_test_std[i]
            );
        }
        let last = curves.ks.len() - 1;
        println!(
            "# greedy {:.3} vs random {:.3} at k={} — greedy dominates: {}",
            curves.greedy_test[last],
            curves.random_test[last],
            curves.ks[last],
            curves
                .greedy_test
                .iter()
                .zip(&curves.random_test)
                .filter(|(g, r)| g >= r)
                .count()
                >= curves.ks.len() / 2
        );
    }
    Ok(())
}
