#!/usr/bin/env python3
"""Tolerance-banded perf-regression comparator for BENCH_hotpath.json.

CI runs the microbench in smoke mode and hands the fresh
`BENCH_hotpath.json` to this script together with the committed
baseline (`xtask/perf_baseline/BENCH_hotpath.json`). Rows are matched
on (m, n, kernel, precision, threads); a row regresses when its median
`score_ms` or `commit_ms` exceeds the baseline by more than the
tolerance band. The band is wide on purpose: smoke problems are tiny
and shared runners are noisy — this gate catches multi-x cliffs (an
accidentally quadratic scan, a lost parallel path), not 3% drift.

Bootstrap: if the baseline file does not exist the comparison is
SKIPPED with a visible notice and exit 0. The baseline must be a real
measured artifact from a trusted CI run, reviewed and committed —
never a hand-written number.

Grid-shape rules: rows present only in the current run (a new kernel
or precision in the sweep) are reported and ignored; rows present only
in the baseline fail, because a silently shrunken grid would let a
regression hide by not being measured.

Usage:
    python3 xtask/mirror/perf_check.py --baseline PATH --current PATH
        [--tolerance 0.5]
    python3 xtask/mirror/perf_check.py --self-test
"""

import json
import os
import sys

METRICS = ["score_ms", "commit_ms"]


def row_key(row):
    return (
        row["m"],
        row["n"],
        row.get("kernel", "scalar"),
        row.get("precision", "f64"),
        row["threads"],
    )


def fmt_key(key):
    m, n, kernel, precision, threads = key
    return f"m={m} n={n} kernel={kernel} precision={precision} t={threads}"


def load_rows(path):
    with open(path) as f:
        doc = json.load(f)
    return {row_key(r): r for r in doc["results"]}


def compare(baseline, current, tolerance):
    """Returns (regressions, notes) — regressions is a list of strings;
    non-empty means fail."""
    regressions, notes = [], []
    for key, base_row in sorted(baseline.items()):
        cur_row = current.get(key)
        if cur_row is None:
            regressions.append(
                f"{fmt_key(key)}: row vanished from the current run — "
                "the measured grid must not shrink"
            )
            continue
        for metric in METRICS:
            base, cur = base_row.get(metric), cur_row.get(metric)
            if base is None or cur is None or base <= 0.0:
                continue
            limit = base * (1.0 + tolerance)
            if cur > limit:
                regressions.append(
                    f"{fmt_key(key)}: {metric} {cur:.3f}ms exceeds "
                    f"baseline {base:.3f}ms by more than "
                    f"{tolerance:.0%} (limit {limit:.3f}ms)"
                )
    for key in sorted(set(current) - set(baseline)):
        notes.append(
            f"{fmt_key(key)}: new row (not in baseline) — measured but "
            "not gated; re-pin the baseline to start gating it"
        )
    return regressions, notes


def self_test():
    base = {
        (200, 64, "scalar", "f64", 1): {
            "m": 200, "n": 64, "kernel": "scalar", "precision": "f64",
            "threads": 1, "score_ms": 1.0, "commit_ms": 0.5,
        },
        (200, 64, "scalar", "f64", 2): {
            "m": 200, "n": 64, "kernel": "scalar", "precision": "f64",
            "threads": 2, "score_ms": 0.6, "commit_ms": 0.3,
        },
    }
    # within band: +40% under a 50% band passes
    cur_ok = {
        k: dict(v, score_ms=v["score_ms"] * 1.4, commit_ms=v["commit_ms"])
        for k, v in base.items()
    }
    reg, _ = compare(base, cur_ok, 0.5)
    assert not reg, reg
    # outside band: +60% fails, and names the row and metric
    cur_bad = {
        k: dict(v, score_ms=v["score_ms"] * 1.6)
        for k, v in base.items()
    }
    reg, _ = compare(base, cur_bad, 0.5)
    assert len(reg) == 2 and "score_ms" in reg[0], reg
    # a vanished row fails even when every surviving row is faster
    cur_shrunk = {
        k: dict(v, score_ms=v["score_ms"] * 0.5)
        for k, v in list(base.items())[:1]
    }
    reg, _ = compare(base, cur_shrunk, 0.5)
    assert len(reg) == 1 and "vanished" in reg[0], reg
    # new rows are notes, not failures
    extra_key = (200, 64, "simd", "f64", 1)
    cur_grown = dict(cur_ok)
    cur_grown[extra_key] = dict(
        base[(200, 64, "scalar", "f64", 1)], kernel="simd"
    )
    reg, notes = compare(base, cur_grown, 0.5)
    assert not reg and len(notes) == 1 and "new row" in notes[0], (reg, notes)
    print("perf_check: self-test OK")


def main():
    argv = sys.argv[1:]
    baseline_path = current_path = None
    tolerance = 0.5
    i = 0
    while i < len(argv):
        if argv[i] == "--baseline":
            baseline_path = argv[i + 1]
            i += 2
        elif argv[i] == "--current":
            current_path = argv[i + 1]
            i += 2
        elif argv[i] == "--tolerance":
            tolerance = float(argv[i + 1])
            i += 2
        elif argv[i] == "--self-test":
            self_test()
            return
        else:
            sys.exit(f"unknown argument {argv[i]!r}")
    if baseline_path is None or current_path is None:
        sys.exit("perf_check: --baseline and --current are required")
    if not os.path.exists(baseline_path):
        print(
            f"perf_check: SKIP — no baseline at {baseline_path}; commit a "
            "reviewed BENCH_hotpath.json from a trusted CI run to arm "
            "this gate"
        )
        return
    baseline = load_rows(baseline_path)
    current = load_rows(current_path)
    regressions, notes = compare(baseline, current, tolerance)
    for note in notes:
        print(f"perf_check: note: {note}")
    for reg in regressions:
        print(f"perf_check: REGRESSION: {reg}")
    print(
        f"perf_check: {len(baseline)} baseline row(s), "
        f"{len(regressions)} regression(s), tolerance {tolerance:.0%}"
    )
    sys.exit(1 if regressions else 0)


if __name__ == "__main__":
    main()
