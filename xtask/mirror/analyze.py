#!/usr/bin/env python3
"""Python mirror of the xtask lint engine (`cargo run -p xtask -- analyze`).

The development environment for this repo is air-gapped and has no Rust
toolchain, so the Rust implementation under `xtask/src/` cannot run
locally. This file is a line-for-line port of the lexer and the
rules: it lets a toolchain-less environment burn findings down to zero
and (re)generate the checkpoint-format pin with the identical FNV-1a
hash the Rust binary computes in CI.

Keep the two implementations in lockstep: any change to
`xtask/src/lexer.rs` or `xtask/src/rules.rs` must land here too (the
`shipped_tree_is_clean` test in `xtask/tests/` fails in CI if the Rust
side disagrees with a tree this mirror accepted).

Usage:
    python3 xtask/mirror/analyze.py [--root DIR] [--json PATH]
    python3 xtask/mirror/analyze.py --pin [--root DIR]
"""

import json
import os
import sys

# ---------------------------------------------------------------------
# lexer (port of xtask/src/lexer.rs)

NORMAL, BLOCK, STR, RAWSTR = "normal", "block", "str", "rawstr"


def _prev_is_ident(b, i):
    return i > 0 and (b[i - 1].isalnum() or b[i - 1] == "_")


def _raw_str_hashes(b, frm):
    j, h = frm, 0
    while j < len(b) and b[j] == "#":
        h += 1
        j += 1
    if j < len(b) and b[j] == '"':
        return h
    return None


def split_line(raw, state, depth_arg):
    """Returns (code, comment, state) — state is (kind, n)."""
    b = list(raw)
    code, comment = [], []
    i = 0
    kind, n = state
    while i < len(b):
        if kind == BLOCK:
            if b[i] == "*" and i + 1 < len(b) and b[i + 1] == "/":
                kind, n = (BLOCK, n - 1) if n > 1 else (NORMAL, 0)
                i += 2
            elif b[i] == "/" and i + 1 < len(b) and b[i + 1] == "*":
                n += 1
                i += 2
            else:
                comment.append(b[i])
                i += 1
        elif kind == STR:
            if b[i] == "\\":
                i += 2
            elif b[i] == '"':
                code.append('"')
                kind, n = NORMAL, 0
                i += 1
            else:
                i += 1
        elif kind == RAWSTR:
            if b[i] == '"':
                tail = "".join(b[i + 1 : i + 1 + n])
                if tail.count("#") == n and len(tail) == n:
                    code.append('"')
                    kind2, n2 = NORMAL, 0
                    i += 1 + n
                    kind, n = kind2, n2
                    continue
            i += 1
        else:  # NORMAL
            c = b[i]
            if c == "/" and i + 1 < len(b) and b[i + 1] == "/":
                comment.append("".join(b[i + 2 :]))
                i = len(b)
            elif c == "/" and i + 1 < len(b) and b[i + 1] == "*":
                kind, n = BLOCK, 1
                i += 2
            elif c == '"':
                code.append('"')
                kind, n = STR, 0
                i += 1
            elif (
                c == "r"
                and not _prev_is_ident(b, i)
                and _raw_str_hashes(b, i + 1) is not None
            ):
                h = _raw_str_hashes(b, i + 1)
                code.append('"')
                kind, n = RAWSTR, h
                i += 2 + h
            elif (
                c == "b"
                and not _prev_is_ident(b, i)
                and i + 1 < len(b)
                and b[i + 1] == '"'
            ):
                code.append('"')
                kind, n = STR, 0
                i += 2
            elif c == "'":
                if i + 1 < len(b) and b[i + 1] == "\\":
                    j = i + 2
                    while j < len(b) and b[j] != "'":
                        j += 1
                    code.append("''")
                    i = j + 1
                elif i + 2 < len(b) and b[i + 2] == "'":
                    code.append("''")
                    i += 3
                else:
                    code.append("'")
                    i += 1
            else:
                code.append(c)
                i += 1
    return "".join(code), "".join(comment), (kind, n)


def scan(contents):
    state = (NORMAL, 0)
    lines = []
    pending_test_attr = False
    in_test = False
    depth = 0
    test_depth = 0
    for idx, raw in enumerate(contents.split("\n")):
        code, comment, state = split_line(raw, state, depth)
        entered_in_test = in_test
        trimmed = code.strip()
        if trimmed.startswith("#[cfg(test)]"):
            pending_test_attr = True
        elif pending_test_attr and trimmed and not trimmed.startswith("#["):
            if (
                trimmed.startswith("mod ")
                or trimmed.startswith("pub mod ")
                or trimmed == "mod"
            ):
                if not in_test:
                    in_test = True
                    test_depth = depth
            pending_test_attr = False
        for ch in code:
            if ch == "{":
                depth += 1
            elif ch == "}":
                depth -= 1
                if in_test and depth <= test_depth:
                    in_test = False
        lines.append(
            {
                "number": idx + 1,
                "code": code,
                "comment": comment,
                "in_test": entered_in_test or in_test,
            }
        )
    # contents.split("\n") yields a trailing empty line for files ending
    # in \n that Rust's .lines() does not — drop it to stay in lockstep
    if lines and contents.endswith("\n"):
        lines.pop()
    allows = collect_allows(lines)
    return lines, allows


def collect_allows(lines):
    out = []
    for i, line in enumerate(lines):
        pos = line["comment"].find("xtask-allow:")
        if pos < 0:
            continue
        rest = line["comment"][pos + len("xtask-allow:") :].strip()
        if "--" in rest:
            rule, justification = rest.split("--", 1)
            rule, justification = rule.strip(), justification.strip()
        else:
            rule, justification = rest, ""
        if line["code"].strip():
            target_line = line["number"]
        else:
            target_line = line["number"]
            for nxt in lines[i + 1 :]:
                if nxt["code"].strip():
                    target_line = nxt["number"]
                    break
        out.append(
            {
                "rule": rule,
                "justification": justification,
                "target_line": target_line,
                "line": line["number"],
            }
        )
    return out


# ---------------------------------------------------------------------
# rules (port of xtask/src/rules.rs)

PIN_FILE = "xtask/checkpoint_format.pin"
CHECKPOINT_RS = "rust/src/select/checkpoint.rs"
CLI_MOD_RS = "rust/src/cli/mod.rs"
PAR_CALLS = ["par_map(", "map_ranges("]
REDUCTION_TOKENS = ["+=", ".sum()", ".sum::<", ".fold(", ".product()"]


def is_hot_path(rel):
    return (
        rel == "rust/src/main.rs"
        or rel.startswith("rust/src/cli/")
        or rel.startswith("rust/src/parallel/")
        or rel == "rust/src/coordinator/serve.rs"
        or rel == "rust/src/coordinator/stream.rs"
        or rel.startswith("rust/src/coordinator/fabric/")
        or rel == "rust/src/select/greedy.rs"
        or rel == "rust/src/data/storage.rs"
    )


def has_config_literal(code):
    search = 0
    while True:
        p = code.find("SelectionConfig", search)
        if p < 0:
            return False
        after = p + len("SelectionConfig")
        if code[after:].lstrip().startswith("{"):
            return True
        search = after


def finding(rule, file, line, message):
    return {"rule": rule, "file": file, "line": line, "message": message}


def token_rules(rel, lines, out):
    hot = is_hot_path(rel)
    for line in lines:
        if line["in_test"]:
            continue
        code = line["code"]
        if hot:
            for tok in [".unwrap()", ".expect(", "panic!"]:
                if tok in code:
                    out.append(
                        finding(
                            "no-panic-hot-path",
                            rel,
                            line["number"],
                            f"`{tok}` in a serving/hot-path module",
                        )
                    )
        if rel != "rust/src/select/session.rs" and "Instant::now" in code:
            out.append(
                finding(
                    "no-raw-instant",
                    rel,
                    line["number"],
                    "raw `Instant::now()` outside the session clock",
                )
            )
        if rel != "rust/src/select/mod.rs" and has_config_literal(code):
            out.append(
                finding(
                    "config-via-builder",
                    rel,
                    line["number"],
                    "`SelectionConfig { … }` struct literal bypasses the builder",
                )
            )


def find_par_call(code, frm):
    best = None
    for pat in PAR_CALLS:
        p = code.find(pat, frm)
        if p >= 0:
            end = p + len(pat)
            best = end if best is None else min(best, end)
    return best


def float_reduction(rel, lines, out):
    for i, line in enumerate(lines):
        if line["in_test"]:
            continue
        code = line["code"]
        frm = 0
        while True:
            open_ = find_par_call(code, frm)
            if open_ is None:
                break
            scan_call_extent(rel, lines, i, open_, out)
            frm = open_


def scan_call_extent(rel, lines, start_line, start_off, out):
    depth = 1
    li = start_line
    while depth > 0 and li < len(lines):
        code = lines[li]["code"]
        begin = start_off if li == start_line else 0
        end = len(code)
        for j in range(begin, len(code)):
            if code[j] == "(":
                depth += 1
            elif code[j] == ")":
                depth -= 1
                if depth == 0:
                    end = j
                    break
        seg = code[begin:end]
        for tok in REDUCTION_TOKENS:
            if tok in seg:
                out.append(
                    finding(
                        "serial-float-reduction",
                        rel,
                        lines[li]["number"],
                        f"`{tok}` inside a par_map/map_ranges call extent",
                    )
                )
        li += 1


def is_kernel_scope(rel):
    return (
        rel.startswith("rust/src/select/")
        or rel == "rust/src/data/storage.rs"
    )


def has_raw_axpy(code):
    for op in ["+=", "-="]:
        p = code.find(op)
        if p >= 0 and "*" in code[p + len(op) :]:
            return True
    return False


def scan_via_kernel(rel, lines, out):
    if not is_kernel_scope(rel):
        return
    for line in lines:
        if line["in_test"]:
            continue
        if has_raw_axpy(line["code"]):
            out.append(
                finding(
                    "scan-via-kernel",
                    rel,
                    line["number"],
                    "raw multiply-accumulate loop in selector/storage "
                    "code — route the inner loop through crate::kernel",
                )
            )


def is_fabric_io(rel):
    return (
        rel.startswith("rust/src/coordinator/fabric/")
        or rel == "rust/src/coordinator/serve.rs"
    )


UNBOUNDED_IO_TOKENS = [
    (
        "TcpStream::connect(",
        "`TcpStream::connect` blocks without a deadline — use "
        "`TcpStream::connect_timeout`",
    ),
    (
        "UnixStream::connect(",
        "unix connect has no deadline in std — arm read/write timeouts "
        "immediately after and justify the connect with an xtask-allow",
    ),
    (
        ".read_to_end(",
        "unbounded socket read — frame reads must be length-prefixed "
        "and validated before allocation",
    ),
    (
        ".read_to_string(",
        "unbounded socket read — frame reads must be length-prefixed "
        "and validated before allocation",
    ),
    (
        "set_read_timeout(None",
        "disabling the read deadline lets a silent peer hang this "
        "worker forever",
    ),
]


def is_storage_io(rel):
    return rel == "rust/src/data/storage.rs"


STORAGE_IO_TOKENS = [
    (
        ".read_to_end(",
        "unbounded file read in the storage layer — stream through "
        "fixed-size chunk refills so memory stays capped at the "
        "configured chunk/window size",
    ),
    (
        ".read_to_string(",
        "unbounded file read in the storage layer — stream through "
        "fixed-size chunk refills so memory stays capped at the "
        "configured chunk/window size",
    ),
]


def unbounded_io(rel, lines, out):
    if is_storage_io(rel):
        for line in lines:
            if line["in_test"]:
                continue
            code = line["code"]
            for tok, why in STORAGE_IO_TOKENS:
                if tok in code:
                    out.append(
                        finding("no-unbounded-io", rel, line["number"], why)
                    )
        return
    if not is_fabric_io(rel):
        return
    connects = False
    arms_read_timeout = False
    for line in lines:
        if line["in_test"]:
            continue
        code = line["code"]
        for tok, why in UNBOUNDED_IO_TOKENS:
            if tok in code:
                out.append(
                    finding("no-unbounded-io", rel, line["number"], why)
                )
        if (
            "TcpStream::connect_timeout(" in code
            or "UnixStream::connect(" in code
        ):
            connects = True
        if "set_read_timeout(" in code:
            arms_read_timeout = True
    if connects and not arms_read_timeout:
        out.append(
            finding(
                "no-unbounded-io",
                rel,
                0,
                "this file opens socket connections but never arms "
                "a read timeout (`set_read_timeout`) — a silent "
                "peer would block its readers forever",
            )
        )


def extract_usage_const(cli_src):
    marker = 'pub const USAGE: &str = "'
    start = cli_src.find(marker)
    if start < 0:
        return None
    body_start = start + len(marker)
    end = cli_src.find('\n";', body_start)
    if end < 0:
        return None
    return cli_src[body_start:end]


def usage_commands(usage):
    out = []
    for line in usage.split("\n"):
        if not line.startswith("  ") or line[2:3] in ("", " "):
            continue
        tok = line[2:].split()[0]
        if tok not in out:
            out.append(tok)
    return out


def readme_commands(section):
    out = []
    for line in section.split("\n"):
        t = line.strip()
        if not t.startswith("| `"):
            continue
        rest = t[3:]
        cell_end = rest.find("`")
        if cell_end < 0:
            continue
        parts = rest[:cell_end].split()
        if parts and parts[0] not in out:
            out.append(parts[0])
    return out


def _is_flag_char(c):
    return c.islower() or c.isdigit() or c == "-"


def flag_tokens(text):
    out = []
    i = 0
    while i + 2 < len(text):
        if (
            text[i] == "-"
            and text[i + 1] == "-"
            and text[i + 2].islower()
            and text[i + 2].isascii()
            and (i == 0 or not _is_flag_char(text[i - 1]))
        ):
            j = i + 2
            while j < len(text) and _is_flag_char(text[j]):
                j += 1
            tok = text[i + 2 : j].rstrip("-")
            if tok not in out:
                out.append(tok)
            i = j
        else:
            i += 1
    return sorted(out)


def extract_readme_section(readme, heading):
    in_section = False
    out = []
    for line in readme.split("\n"):
        if line.rstrip() == heading:
            in_section = True
            continue
        if in_section and line.startswith("## "):
            break
        if in_section:
            out.append(line)
    return "\n".join(out) + "\n" if in_section else None


def diff_sets(out, kind, usage, readme, usage_name, readme_name):
    for item in usage:
        if item not in readme:
            out.append(
                finding(
                    "usage-drift",
                    "README.md",
                    0,
                    f"{kind} `{item}` is in {usage_name} but missing from "
                    f"{readme_name}",
                )
            )
    for item in readme:
        if item not in usage:
            out.append(
                finding(
                    "usage-drift",
                    "README.md",
                    0,
                    f"{kind} `{item}` is in {readme_name} but not in "
                    f"{usage_name}",
                )
            )


def usage_drift(root, out):
    with open(os.path.join(root, CLI_MOD_RS)) as f:
        cli = f.read()
    with open(os.path.join(root, "README.md")) as f:
        readme = f.read()
    usage = extract_usage_const(cli)
    if usage is None:
        out.append(
            finding("usage-drift", CLI_MOD_RS, 0, "USAGE const not found")
        )
        return
    section = extract_readme_section(readme, "## CLI reference")
    if section is None:
        out.append(
            finding(
                "usage-drift", "README.md", 0, "no `## CLI reference` section"
            )
        )
        return
    diff_sets(
        out,
        "command",
        usage_commands(usage),
        readme_commands(section),
        "cli/mod.rs USAGE",
        "README.md §CLI reference",
    )
    diff_sets(
        out,
        "flag",
        flag_tokens(usage),
        flag_tokens(section),
        "cli/mod.rs USAGE",
        "README.md §CLI reference",
    )


def parse_format_version(contents):
    marker = "FORMAT_VERSION: u32 ="
    p = contents.find(marker)
    if p < 0:
        return None
    rest = contents[p + len(marker) :].lstrip()
    digits = ""
    for c in rest:
        if c.isdigit():
            digits += c
        else:
            break
    return int(digits) if digits else None


def checkpoint_fingerprint(root):
    with open(os.path.join(root, CHECKPOINT_RS)) as f:
        contents = f.read()
    version = parse_format_version(contents)
    if version is None:
        raise ValueError("FORMAT_VERSION constant not found in checkpoint.rs")
    lines, _allows = scan(contents)
    h = 0xCBF29CE484222325
    raws = contents.split("\n")
    if contents.endswith("\n"):
        raws.pop()
    for raw, line in zip(raws, lines):
        if line["in_test"]:
            continue
        for byte in raw.encode("utf-8"):
            h = ((h ^ byte) * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
        h = ((h ^ 0x0A) * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
    return version, h


def pin_contents(root):
    version, h = checkpoint_fingerprint(root)
    return (
        "# Pin guarding rule `checkpoint-format-pin`: the FNV-1a hash of\n"
        "# rust/src/select/checkpoint.rs (test modules excluded) at the\n"
        "# last reviewed FORMAT_VERSION. A hash change without a version\n"
        "# bump means serialization may have drifted silently; refresh\n"
        "# with `cargo run -p xtask -- pin` after review.\n"
        f"format_version = {version}\n"
        f"source_hash = fnv1a64:{h:016x}\n"
    )


def pin_field(pin, key):
    for line in pin.split("\n"):
        t = line.strip()
        if t.startswith(key):
            rest = t[len(key) :].lstrip()
            if rest.startswith("="):
                return rest[1:].strip()
    return None


def checkpoint_pin(root, out):
    version, h = checkpoint_fingerprint(root)
    path = os.path.join(root, PIN_FILE)
    try:
        with open(path) as f:
            pin = f.read()
    except OSError:
        out.append(
            finding(
                "checkpoint-format-pin",
                PIN_FILE,
                0,
                "pin file missing — run `cargo run -p xtask -- pin`",
            )
        )
        return
    pv = pin_field(pin, "format_version")
    ph = pin_field(pin, "source_hash")
    try:
        pv = int(pv)
        assert ph.startswith("fnv1a64:")
        ph = int(ph[len("fnv1a64:") :], 16)
    except (TypeError, ValueError, AssertionError, AttributeError):
        out.append(
            finding("checkpoint-format-pin", PIN_FILE, 0, "pin malformed")
        )
        return
    if pv != version:
        out.append(
            finding(
                "checkpoint-format-pin",
                PIN_FILE,
                0,
                f"pin is stale (FORMAT_VERSION {pv} pinned, {version} in "
                "checkpoint.rs) — re-pin",
            )
        )
    elif ph != h:
        out.append(
            finding(
                "checkpoint-format-pin",
                CHECKPOINT_RS,
                0,
                f"checkpoint.rs (non-test) changed but FORMAT_VERSION is "
                f"still {version} — bump it or re-pin",
            )
        )


def resolve_allows(scans, raw):
    allows = []
    for rel, lines, file_allows in scans:
        for a in file_allows:
            allows.append([rel, a, False])
    findings, suppressed = [], []
    for f in raw:
        hit = None
        for entry in allows:
            rel, a, _used = entry
            if (
                rel == f["file"]
                and a["rule"] == f["rule"]
                and a["target_line"] == f["line"]
            ):
                hit = entry
                break
        if hit is not None and hit[1]["justification"]:
            hit[2] = True
            suppressed.append(
                {
                    "rule": hit[1]["rule"],
                    "file": hit[0],
                    "line": hit[1]["target_line"],
                    "justification": hit[1]["justification"],
                }
            )
        elif hit is not None:
            hit[2] = True
            findings.append(
                finding(
                    "allow-hygiene",
                    f["file"],
                    hit[1]["line"],
                    f"xtask-allow for `{hit[1]['rule']}` has no "
                    "`-- justification`",
                )
            )
            findings.append(f)
        else:
            findings.append(f)
    for rel, a, used in allows:
        if not used:
            findings.append(
                finding(
                    "allow-hygiene",
                    rel,
                    a["line"],
                    f"stale xtask-allow: no `{a['rule']}` finding targets "
                    f"line {a['target_line']}",
                )
            )
    return findings, suppressed


def analyze(root):
    files = []
    src = os.path.join(root, "rust", "src")
    for dirpath, _dirnames, filenames in os.walk(src):
        for name in filenames:
            if name.endswith(".rs"):
                files.append(os.path.join(dirpath, name))
    files.sort()
    scans = []
    for path in files:
        rel = os.path.relpath(path, root).replace(os.sep, "/")
        with open(path) as f:
            contents = f.read()
        lines, allows = scan(contents)
        scans.append((rel, lines, allows))
    raw = []
    for rel, lines, _allows in scans:
        token_rules(rel, lines, raw)
        float_reduction(rel, lines, raw)
        unbounded_io(rel, lines, raw)
        scan_via_kernel(rel, lines, raw)
    usage_drift(root, raw)
    checkpoint_pin(root, raw)
    findings, suppressed = resolve_allows(scans, raw)
    return {
        "files_scanned": len(scans),
        "finding_count": len(findings),
        "findings": findings,
        "suppressed": suppressed,
    }


def main():
    argv = sys.argv[1:]
    root = "."
    json_path = None
    do_pin = False
    i = 0
    while i < len(argv):
        if argv[i] == "--root":
            root = argv[i + 1]
            i += 2
        elif argv[i] == "--json":
            json_path = argv[i + 1]
            i += 2
        elif argv[i] == "--pin":
            do_pin = True
            i += 1
        else:
            sys.exit(f"unknown argument {argv[i]!r}")
    if do_pin:
        with open(os.path.join(root, PIN_FILE), "w") as f:
            f.write(pin_contents(root))
        print(f"mirror pin: wrote {PIN_FILE}")
        return
    report = analyze(root)
    for f in report["findings"]:
        loc = (
            f"{f['file']}:{f['line']}" if f["line"] else f["file"]
        )
        print(f"[{f['rule']}] {loc}: {f['message']}")
    print(
        f"mirror analyze: {report['files_scanned']} file(s), "
        f"{report['finding_count']} finding(s), "
        f"{len(report['suppressed'])} suppressed"
    )
    if json_path:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=2)
    sys.exit(1 if report["findings"] else 0)


if __name__ == "__main__":
    main()
