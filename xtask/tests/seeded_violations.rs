//! Self-test fixture suite: seed a violation of each of the rules
//! into a minimal synthetic tree and demand `analyze` reports exactly
//! that rule; then demand the *shipped* tree is clean — which makes
//! `cargo test` itself an enforcement point, independent of the CI step
//! that runs the binary.

use std::fs;
use std::path::{Path, PathBuf};

use xtask::Report;

/// Build a minimal tree that every rule passes on, rooted in a unique
/// temp dir per test.
fn clean_fixture(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("xtask-fixture-{name}-{}", std::process::id()));
    let _ = fs::remove_dir_all(&dir);
    for sub in [
        "rust/src/coordinator",
        "rust/src/select",
        "rust/src/parallel",
        "rust/src/cli",
        "xtask",
    ] {
        fs::create_dir_all(dir.join(sub)).unwrap();
    }

    fs::write(
        dir.join("rust/src/coordinator/serve.rs"),
        "pub fn serve() -> Result<(), String> {\n    Ok(())\n}\n",
    )
    .unwrap();

    fs::write(
        dir.join("rust/src/select/session.rs"),
        "use std::time::Instant;\n\npub fn clock() -> Instant {\n    \
         Instant::now()\n}\n",
    )
    .unwrap();

    fs::write(
        dir.join("rust/src/select/mod.rs"),
        "pub struct SelectionConfig {\n    pub k: usize,\n}\n\nimpl \
         Default for SelectionConfig {\n    fn default() -> Self {\n        \
         SelectionConfig { k: 10 }\n    }\n}\n",
    )
    .unwrap();

    fs::write(
        dir.join("rust/src/parallel/mod.rs"),
        "pub fn map_ranges<F: Fn(usize) -> f64>(n: usize, f: F) -> \
         Vec<f64> {\n    (0..n).map(f).collect()\n}\n\npub fn caller() -> \
         Vec<f64> {\n    map_ranges(3, |i| i as f64)\n}\n",
    )
    .unwrap();

    fs::write(
        dir.join("rust/src/cli/mod.rs"),
        concat!(
            "pub const USAGE: &str = \"\\\n",
            "fixture usage\n",
            "\n",
            "USAGE: greedy-rls <command> [flags]\n",
            "\n",
            "COMMANDS\n",
            "  select     run selection\n",
            "             --k K [--threads T]\n",
            "  help       this text\n",
            "\";\n",
        ),
    )
    .unwrap();

    fs::write(
        dir.join("README.md"),
        concat!(
            "# fixture\n",
            "\n",
            "## CLI reference\n",
            "\n",
            "| command | purpose | own flags |\n",
            "|---|---|---|\n",
            "| `select` | run | `--k K`, `--threads T` |\n",
            "| `help` | usage text | none |\n",
            "\n",
            "## Other\n",
            "\n",
            "unrelated\n",
        ),
    )
    .unwrap();

    fs::write(
        dir.join("rust/src/select/checkpoint.rs"),
        "pub const FORMAT_VERSION: u32 = 1;\n\npub fn to_text() -> \
         String {\n    String::from(\"v1\")\n}\n\n#[cfg(test)]\nmod tests \
         {\n    #[test]\n    fn t() {\n        assert_eq!(super::to_text(), \
         \"v1\");\n    }\n}\n",
    )
    .unwrap();

    xtask::write_pin(&dir).unwrap();
    dir
}

fn rules_found(report: &Report) -> Vec<String> {
    let mut rules: Vec<String> =
        report.findings.iter().map(|f| f.rule.clone()).collect();
    rules.sort();
    rules.dedup();
    rules
}

fn append(path: &Path, text: &str) {
    let mut contents = fs::read_to_string(path).unwrap();
    contents.push_str(text);
    fs::write(path, contents).unwrap();
}

#[test]
fn clean_fixture_passes() {
    let dir = clean_fixture("clean");
    let r = xtask::analyze(&dir).unwrap();
    assert!(r.clean(), "expected clean, got: {:?}", r.findings);
    assert_eq!(r.files_scanned, 6);
}

#[test]
fn seeded_unwrap_in_hot_path_fires() {
    let dir = clean_fixture("rule1");
    append(
        &dir.join("rust/src/coordinator/serve.rs"),
        "\npub fn bad() {\n    let x: Option<u32> = None;\n    \
         x.unwrap();\n}\n",
    );
    let r = xtask::analyze(&dir).unwrap();
    assert_eq!(rules_found(&r), ["no-panic-hot-path"]);
}

#[test]
fn seeded_expect_and_panic_fire_too() {
    let dir = clean_fixture("rule1b");
    append(
        &dir.join("rust/src/parallel/mod.rs"),
        "\npub fn bad(o: Option<u32>) -> u32 {\n    if o.is_none() {\n        \
         panic!(\"no\");\n    }\n    o.expect(\"checked\")\n}\n",
    );
    let r = xtask::analyze(&dir).unwrap();
    assert_eq!(rules_found(&r), ["no-panic-hot-path"]);
    assert_eq!(r.findings.len(), 2);
}

#[test]
fn unwrap_inside_cfg_test_is_ignored() {
    let dir = clean_fixture("rule1c");
    append(
        &dir.join("rust/src/coordinator/serve.rs"),
        "\n#[cfg(test)]\nmod tests {\n    #[test]\n    fn t() {\n        \
         Some(1u32).unwrap();\n    }\n}\n",
    );
    let r = xtask::analyze(&dir).unwrap();
    assert!(r.clean(), "test-mod unwrap must not fire: {:?}", r.findings);
}

#[test]
fn seeded_raw_instant_fires() {
    let dir = clean_fixture("rule2");
    append(
        &dir.join("rust/src/coordinator/serve.rs"),
        "\npub fn t0() -> std::time::Instant {\n    \
         std::time::Instant::now()\n}\n",
    );
    let r = xtask::analyze(&dir).unwrap();
    assert_eq!(rules_found(&r), ["no-raw-instant"]);
}

#[test]
fn session_clock_instant_is_exempt() {
    let dir = clean_fixture("rule2b");
    // the clean fixture's session.rs already calls Instant::now()
    let r = xtask::analyze(&dir).unwrap();
    assert!(r.clean());
}

#[test]
fn seeded_config_literal_fires() {
    let dir = clean_fixture("rule3");
    fs::write(
        dir.join("rust/src/other.rs"),
        "pub fn c() {\n    let _ = SelectionConfig { k: 1 };\n}\n",
    )
    .unwrap();
    let r = xtask::analyze(&dir).unwrap();
    assert_eq!(rules_found(&r), ["config-via-builder"]);
}

#[test]
fn seeded_float_reduction_fires() {
    let dir = clean_fixture("rule4");
    append(
        &dir.join("rust/src/parallel/mod.rs"),
        "\npub fn bad_caller() -> Vec<f64> {\n    map_ranges(3, |i| {\n        \
         let mut s = 0.0;\n        s += i as f64;\n        s\n    })\n}\n",
    );
    let r = xtask::analyze(&dir).unwrap();
    assert_eq!(rules_found(&r), ["serial-float-reduction"]);
}

#[test]
fn float_accumulation_outside_call_extent_is_fine() {
    let dir = clean_fixture("rule4b");
    append(
        &dir.join("rust/src/parallel/mod.rs"),
        "\npub fn serial_reduce() -> f64 {\n    let mut acc = 0.0;\n    \
         for v in caller() {\n        acc += v;\n    }\n    acc\n}\n",
    );
    let r = xtask::analyze(&dir).unwrap();
    assert!(r.clean(), "serial reduction must not fire: {:?}", r.findings);
}

#[test]
fn seeded_usage_drift_fires() {
    let dir = clean_fixture("rule5");
    // drop the `help` row and document a flag the CLI does not have
    fs::write(
        dir.join("README.md"),
        concat!(
            "# fixture\n",
            "\n",
            "## CLI reference\n",
            "\n",
            "| command | purpose | own flags |\n",
            "|---|---|---|\n",
            "| `select` | run | `--k K`, `--threads T`, `--ghost G` |\n",
            "\n",
            "## Other\n",
        ),
    )
    .unwrap();
    let r = xtask::analyze(&dir).unwrap();
    assert_eq!(rules_found(&r), ["usage-drift"]);
    // one missing command, one phantom flag
    assert_eq!(r.findings.len(), 2);
}

#[test]
fn seeded_checkpoint_hash_drift_fires() {
    let dir = clean_fixture("rule6");
    append(
        &dir.join("rust/src/select/checkpoint.rs"),
        "\npub fn extra_serialization_path() -> String {\n    \
         String::from(\"v1-extended\")\n}\n",
    );
    let r = xtask::analyze(&dir).unwrap();
    assert_eq!(rules_found(&r), ["checkpoint-format-pin"]);
}

#[test]
fn checkpoint_test_churn_does_not_fire() {
    let dir = clean_fixture("rule6b");
    append(
        &dir.join("rust/src/select/checkpoint.rs"),
        "\n#[cfg(test)]\nmod more_tests {\n    #[test]\n    fn extra() \
         {\n        assert!(true);\n    }\n}\n",
    );
    let r = xtask::analyze(&dir).unwrap();
    assert!(r.clean(), "test-only churn must not fire: {:?}", r.findings);
}

#[test]
fn version_bump_without_repin_fires() {
    let dir = clean_fixture("rule6c");
    let path = dir.join("rust/src/select/checkpoint.rs");
    let contents = fs::read_to_string(&path)
        .unwrap()
        .replace("FORMAT_VERSION: u32 = 1", "FORMAT_VERSION: u32 = 2");
    fs::write(&path, contents).unwrap();
    let r = xtask::analyze(&dir).unwrap();
    assert_eq!(rules_found(&r), ["checkpoint-format-pin"]);
    assert!(r.findings[0].message.contains("stale"));
    // re-pinning resolves it
    xtask::write_pin(&dir).unwrap();
    assert!(xtask::analyze(&dir).unwrap().clean());
}

#[test]
fn seeded_unbounded_read_fires() {
    let dir = clean_fixture("rule7");
    append(
        &dir.join("rust/src/coordinator/serve.rs"),
        "\npub fn slurp(s: &mut std::net::TcpStream) -> Vec<u8> {\n    \
         let mut buf = Vec::new();\n    let _ = s.read_to_end(&mut \
         buf);\n    buf\n}\n",
    );
    let r = xtask::analyze(&dir).unwrap();
    assert_eq!(rules_found(&r), ["no-unbounded-io"]);
}

#[test]
fn connect_without_read_timeout_fires_at_file_level() {
    let dir = clean_fixture("rule7b");
    // connect_timeout is not a banned token, so only the file-level
    // pairing check (line 0, not allow-able) should fire
    append(
        &dir.join("rust/src/coordinator/serve.rs"),
        "\npub fn dial(a: &std::net::SocketAddr) {\n    let _ = \
         std::net::TcpStream::connect_timeout(a, \
         std::time::Duration::from_secs(1));\n}\n",
    );
    let r = xtask::analyze(&dir).unwrap();
    assert_eq!(rules_found(&r), ["no-unbounded-io"]);
    assert_eq!(r.findings.len(), 1);
    assert_eq!(r.findings[0].line, 0);
    assert!(r.findings[0].message.contains("never arms"));
}

/// Seed `rust/src/data/storage.rs` into a fixture (the clean fixture
/// does not carry one, keeping its `files_scanned == 6` stable).
fn seed_storage_rs(dir: &Path, body: &str) {
    fs::create_dir_all(dir.join("rust/src/data")).unwrap();
    fs::write(dir.join("rust/src/data/storage.rs"), body).unwrap();
}

#[test]
fn seeded_unwrap_in_storage_fires() {
    let dir = clean_fixture("rule1d");
    seed_storage_rs(
        &dir,
        "pub fn window() -> u64 {\n    let cap: Option<u64> = None;\n    \
         cap.unwrap()\n}\n",
    );
    let r = xtask::analyze(&dir).unwrap();
    assert_eq!(rules_found(&r), ["no-panic-hot-path"]);
}

#[test]
fn seeded_whole_file_read_in_storage_fires() {
    let dir = clean_fixture("rule7e");
    seed_storage_rs(
        &dir,
        "use std::io::Read;\n\npub fn slurp(f: &mut std::fs::File) -> \
         Vec<u8> {\n    let mut buf = Vec::new();\n    let _ = \
         f.read_to_end(&mut buf);\n    buf\n}\n",
    );
    let r = xtask::analyze(&dir).unwrap();
    assert_eq!(rules_found(&r), ["no-unbounded-io"]);
    assert!(r.findings[0].message.contains("storage layer"));
}

#[test]
fn storage_scope_skips_socket_pairing_checks() {
    let dir = clean_fixture("rule7f");
    // socket tokens and the connect/timeout pairing check are fabric
    // rules; in storage.rs only the whole-file-read tokens apply
    seed_storage_rs(
        &dir,
        "pub fn dial(a: &std::net::SocketAddr) {\n    let _ = \
         std::net::TcpStream::connect_timeout(a, \
         std::time::Duration::from_secs(1));\n}\n",
    );
    let r = xtask::analyze(&dir).unwrap();
    assert!(r.clean(), "fabric pairing fired in storage: {:?}", r.findings);
}

#[test]
fn storage_test_module_reads_are_exempt() {
    let dir = clean_fixture("rule7g");
    seed_storage_rs(
        &dir,
        "pub fn fine() {}\n\n#[cfg(test)]\nmod tests {\n    use \
         std::io::Read;\n\n    #[test]\n    fn t() {\n        let mut buf = \
         Vec::new();\n        let mut f = \
         std::fs::File::open(\"x\").unwrap();\n        let _ = \
         f.read_to_end(&mut buf);\n    }\n}\n",
    );
    let r = xtask::analyze(&dir).unwrap();
    assert!(r.clean(), "test-mod read must not fire: {:?}", r.findings);
}

#[test]
fn unbounded_io_outside_fabric_scope_is_ignored() {
    let dir = clean_fixture("rule7c");
    fs::write(
        dir.join("rust/src/other_io.rs"),
        "pub fn slurp(s: &mut std::net::TcpStream) -> Vec<u8> {\n    \
         let mut buf = Vec::new();\n    let _ = s.read_to_end(&mut \
         buf);\n    buf\n}\n",
    )
    .unwrap();
    let r = xtask::analyze(&dir).unwrap();
    assert!(r.clean(), "non-fabric io must not fire: {:?}", r.findings);
}

#[test]
fn justified_allow_suppresses_unbounded_io() {
    let dir = clean_fixture("rule7d");
    append(
        &dir.join("rust/src/coordinator/serve.rs"),
        "\npub fn park(s: &std::net::TcpStream) {\n    // xtask-allow: \
         no-unbounded-io -- fixture exercises the escape hatch\n    \
         let _ = s.set_read_timeout(None);\n}\n",
    );
    let r = xtask::analyze(&dir).unwrap();
    assert!(r.clean(), "justified allow must suppress: {:?}", r.findings);
    assert_eq!(r.suppressed.len(), 1);
    assert_eq!(r.suppressed[0].rule, "no-unbounded-io");
}

#[test]
fn seeded_raw_axpy_in_selector_fires() {
    let dir = clean_fixture("rule8");
    fs::write(
        dir.join("rust/src/select/scan.rs"),
        "pub fn dot(a: &[f64], b: &[f64]) -> f64 {\n    let mut s = 0.0;\n    \
         for (x, y) in a.iter().zip(b) {\n        s += x * y;\n    }\n    \
         s\n}\n",
    )
    .unwrap();
    let r = xtask::analyze(&dir).unwrap();
    assert_eq!(rules_found(&r), ["scan-via-kernel"]);
}

#[test]
fn seeded_raw_axpy_in_sketch_module_fires() {
    // the preselection scorer is in kernel scope like every selector
    // file: a hand-rolled Gram accumulation must route through the
    // kernel tier
    let dir = clean_fixture("rule8e");
    fs::write(
        dir.join("rust/src/select/sketch.rs"),
        "pub fn gram_row(k: &mut [f64], xi: &[f64], w: f64) {\n    for \
         (g, &v) in k.iter_mut().zip(xi) {\n        *g += w * v;\n    }\n}\n",
    )
    .unwrap();
    let r = xtask::analyze(&dir).unwrap();
    assert_eq!(rules_found(&r), ["scan-via-kernel"]);
}

#[test]
fn raw_axpy_in_kernel_tier_is_exempt() {
    let dir = clean_fixture("rule8b");
    // the kernel tier is where these loops are SUPPOSED to live
    fs::create_dir_all(dir.join("rust/src/kernel")).unwrap();
    fs::write(
        dir.join("rust/src/kernel/scalar.rs"),
        "pub fn axpy(a: &mut [f64], u: &[f64], s: f64) {\n    for (x, &v) \
         in a.iter_mut().zip(u) {\n        *x += s * v;\n    }\n}\n",
    )
    .unwrap();
    let r = xtask::analyze(&dir).unwrap();
    assert!(r.clean(), "kernel-tier axpy must not fire: {:?}", r.findings);
}

#[test]
fn raw_axpy_in_selector_test_module_is_exempt() {
    let dir = clean_fixture("rule8c");
    fs::write(
        dir.join("rust/src/select/scan.rs"),
        "pub fn fine() {}\n\n#[cfg(test)]\nmod tests {\n    #[test]\n    \
         fn brute_force_reference() {\n        let mut s = 0.0;\n        \
         for i in 0..4 {\n            s += i as f64 * 2.0;\n        }\n        \
         assert!(s > 0.0);\n    }\n}\n",
    )
    .unwrap();
    let r = xtask::analyze(&dir).unwrap();
    assert!(r.clean(), "test-mod axpy must not fire: {:?}", r.findings);
}

#[test]
fn justified_allow_suppresses_raw_axpy() {
    let dir = clean_fixture("rule8d");
    fs::write(
        dir.join("rust/src/select/scan.rs"),
        "pub fn downdate(g: &mut [f64], gv: &[f64], f: f64) {\n    for \
         (c, &v) in g.iter_mut().zip(gv) {\n        // xtask-allow: \
         scan-via-kernel -- fixture quadratic baseline\n        *c -= f * \
         v;\n    }\n}\n",
    )
    .unwrap();
    let r = xtask::analyze(&dir).unwrap();
    assert!(r.clean(), "justified allow must suppress: {:?}", r.findings);
    assert_eq!(r.suppressed.len(), 1);
    assert_eq!(r.suppressed[0].rule, "scan-via-kernel");
}

#[test]
fn justified_allow_suppresses() {
    let dir = clean_fixture("allow1");
    append(
        &dir.join("rust/src/coordinator/serve.rs"),
        "\npub fn t0() -> std::time::Instant {\n    // xtask-allow: \
         no-raw-instant -- fixture latency measurement\n    \
         std::time::Instant::now()\n}\n",
    );
    let r = xtask::analyze(&dir).unwrap();
    assert!(r.clean(), "justified allow must suppress: {:?}", r.findings);
    assert_eq!(r.suppressed.len(), 1);
    assert_eq!(r.suppressed[0].rule, "no-raw-instant");
}

#[test]
fn unjustified_allow_does_not_suppress() {
    let dir = clean_fixture("allow2");
    append(
        &dir.join("rust/src/coordinator/serve.rs"),
        "\npub fn t0() -> std::time::Instant {\n    // xtask-allow: \
         no-raw-instant\n    std::time::Instant::now()\n}\n",
    );
    let r = xtask::analyze(&dir).unwrap();
    assert_eq!(rules_found(&r), ["allow-hygiene", "no-raw-instant"]);
}

#[test]
fn stale_allow_is_flagged() {
    let dir = clean_fixture("allow3");
    append(
        &dir.join("rust/src/coordinator/serve.rs"),
        "\n// xtask-allow: no-raw-instant -- nothing here anymore\npub fn \
         fine() {}\n",
    );
    let r = xtask::analyze(&dir).unwrap();
    assert_eq!(rules_found(&r), ["allow-hygiene"]);
}

#[test]
fn json_report_shape() {
    let dir = clean_fixture("json");
    append(
        &dir.join("rust/src/coordinator/serve.rs"),
        "\npub fn bad() {\n    let x: Option<u32> = None;\n    \
         x.unwrap();\n}\n",
    );
    let r = xtask::analyze(&dir).unwrap();
    let j = r.to_json();
    assert!(j.contains("\"finding_count\": 1"));
    assert!(j.contains("no-panic-hot-path"));
    assert!(j.contains("coordinator/serve.rs"));
}

/// The acceptance gate: the shipped tree must be clean. This runs under
/// plain `cargo test`, so the invariant holds even where the CI analyze
/// step is not wired.
#[test]
fn shipped_tree_is_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).parent().unwrap();
    let r = xtask::analyze(root).unwrap();
    assert!(
        r.clean(),
        "shipped tree has {} finding(s): {:#?}",
        r.findings.len(),
        r.findings
    );
}
