//! `xtask` — repo maintenance commands.
//!
//! ```text
//! cargo run -p xtask -- analyze [--root DIR] [--json PATH]
//! cargo run -p xtask -- pin     [--root DIR]
//! ```
//!
//! `analyze` exits 0 on a clean tree, 1 on findings, 2 on I/O errors —
//! CI runs it enforcing on stable (see .github/workflows/ci.yml).

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut cmd: Option<&str> = None;
    let mut root = PathBuf::from(".");
    let mut json: Option<PathBuf> = None;
    let mut it = args.iter();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--root" => match it.next() {
                Some(v) => root = PathBuf::from(v),
                None => return usage_error("--root needs a value"),
            },
            "--json" => match it.next() {
                Some(v) => json = Some(PathBuf::from(v)),
                None => return usage_error("--json needs a value"),
            },
            "analyze" | "pin" if cmd.is_none() => cmd = Some(a.as_str()),
            other => {
                return usage_error(&format!("unknown argument {other:?}"))
            }
        }
    }

    match cmd {
        Some("analyze") => run_analyze(&root, json.as_deref()),
        Some("pin") => run_pin(&root),
        _ => usage_error("expected a subcommand: analyze | pin"),
    }
}

fn run_analyze(
    root: &std::path::Path,
    json: Option<&std::path::Path>,
) -> ExitCode {
    let report = match xtask::analyze(root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("xtask analyze: {e}");
            return ExitCode::from(2);
        }
    };
    if let Some(path) = json {
        if let Err(e) = std::fs::write(path, report.to_json()) {
            eprintln!("xtask analyze: writing {}: {e}", path.display());
            return ExitCode::from(2);
        }
    }
    for f in &report.findings {
        let loc = if f.line > 0 {
            format!("{}:{}", f.file, f.line)
        } else {
            f.file.clone()
        };
        println!("[{}] {loc}: {}", f.rule, f.message);
    }
    println!(
        "xtask analyze: {} file(s), {} finding(s), {} suppressed by \
         justified allows",
        report.files_scanned,
        report.findings.len(),
        report.suppressed.len()
    );
    if report.clean() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}

fn run_pin(root: &std::path::Path) -> ExitCode {
    match xtask::write_pin(root) {
        Ok(rel) => {
            println!("xtask pin: wrote {rel}");
            ExitCode::SUCCESS
        }
        Err(e) => {
            eprintln!("xtask pin: {e}");
            ExitCode::from(2)
        }
    }
}

fn usage_error(msg: &str) -> ExitCode {
    eprintln!(
        "xtask: {msg}\nusage: xtask analyze [--root DIR] [--json PATH]\n   \
         or: xtask pin [--root DIR]"
    );
    ExitCode::from(2)
}
