//! Repo-invariant lint engine for greedy-rls.
//!
//! Run as `cargo run -p xtask -- analyze`. The library form exists so
//! the seeded-violation self-tests in `xtask/tests/` can drive the
//! engine over fixture trees without spawning processes.
//!
//! Design constraints, in priority order:
//! 1. **std-only** — the air-gapped build resolves no new dependencies,
//!    so no `syn`, no `regex`, no serde. The [`lexer`] is a line/token
//!    scanner, deliberately not a parser.
//! 2. **Zero findings or justified allows** — every rule supports
//!    `// xtask-allow: <rule> -- <justification>` on (or directly above)
//!    the offending line; [`rules::RULES`] lists the invariants.
//! 3. **Machine-readable** — `analyze --json PATH` writes the
//!    [`report::Report`] for CI artifact upload.

pub mod lexer;
pub mod report;
pub mod rules;

pub use report::{Finding, Report, Suppressed};
pub use rules::{analyze, pin_contents, write_pin, PIN_FILE, RULES};
