//! Line/token-level Rust scanner — the deliberately small front end of
//! the lint engine.
//!
//! Not a parser: each file becomes a `Vec<Line>` where every line carries
//! its *code* text (string/char literals blanked, comments removed), its
//! *comment* text (for `xtask-allow` directives), and whether it sits
//! inside a `#[cfg(test)]` module. That is exactly enough signal for the
//! repo's invariants (token bans, call-extent scans, drift diffs) while
//! staying std-only — no `syn`, no `regex`.
//!
//! Known approximations, acceptable for this codebase's style:
//! - string/char/lifetime disambiguation is heuristic (a `'` followed by
//!   an identifier char and no closing quote two chars later is treated
//!   as a lifetime);
//! - raw strings are recognized for up to any number of `#`s but only
//!   when the `r`/`br` prefix starts a token;
//! - `#[cfg(test)]` regions are tracked by brace depth from the next
//!   `mod` item, which matches the crate's universal `mod tests` idiom.

/// One scanned source line.
#[derive(Clone, Debug)]
pub struct Line {
    /// 1-based line number.
    pub number: usize,
    /// Code text with comments removed and literal contents blanked
    /// (quotes kept, so token shapes like `"..."` stay visible).
    pub code: String,
    /// Concatenated comment text on this line (no `//` / `/*` markers).
    pub comment: String,
    /// True inside a `#[cfg(test)] mod … { … }` region.
    pub in_test: bool,
}

/// An `// xtask-allow: <rule> -- <justification>` directive.
#[derive(Clone, Debug)]
pub struct Allow {
    /// Rule name the directive suppresses.
    pub rule: String,
    /// Justification text after `--` (empty if missing — itself a finding).
    pub justification: String,
    /// Line the directive suppresses: the directive's own line when it
    /// trails code, otherwise the next line carrying code.
    pub target_line: usize,
    /// Line the directive itself is written on.
    pub line: usize,
}

/// A scanned file: lines plus its allow directives.
#[derive(Clone, Debug)]
pub struct ScannedFile {
    pub lines: Vec<Line>,
    pub allows: Vec<Allow>,
}

#[derive(Clone, Copy, PartialEq)]
enum State {
    Normal,
    Block(u32),
    Str,
    RawStr(u32),
}

/// Scan full file contents into [`ScannedFile`].
pub fn scan(contents: &str) -> ScannedFile {
    let mut state = State::Normal;
    let mut lines = Vec::new();

    // #[cfg(test)] region tracking.
    let mut pending_test_attr = false;
    let mut in_test = false;
    let mut depth: i64 = 0;
    let mut test_depth: i64 = 0;

    for (idx, raw) in contents.lines().enumerate() {
        let (code, comment, next) = split_line(raw, state);
        state = next;

        let entered_in_test = in_test;
        let trimmed = code.trim();
        if trimmed.starts_with("#[cfg(test)]") {
            pending_test_attr = true;
        } else if pending_test_attr
            && !trimmed.is_empty()
            && !trimmed.starts_with("#[")
        {
            if trimmed.starts_with("mod ")
                || trimmed.starts_with("pub mod ")
                || trimmed == "mod"
            {
                if !in_test {
                    in_test = true;
                    test_depth = depth;
                }
            }
            pending_test_attr = false;
        }

        for ch in code.chars() {
            match ch {
                '{' => depth += 1,
                '}' => {
                    depth -= 1;
                    if in_test && depth <= test_depth {
                        in_test = false;
                    }
                }
                _ => {}
            }
        }

        lines.push(Line {
            number: idx + 1,
            code,
            comment,
            // The closing `}` line of a test mod still counts as test.
            in_test: entered_in_test || in_test,
        });
    }

    let allows = collect_allows(&lines);
    ScannedFile { lines, allows }
}

fn collect_allows(lines: &[Line]) -> Vec<Allow> {
    let mut out = Vec::new();
    for (i, line) in lines.iter().enumerate() {
        let Some(pos) = line.comment.find("xtask-allow:") else {
            continue;
        };
        let rest = line.comment[pos + "xtask-allow:".len()..].trim();
        let (rule, justification) = match rest.split_once("--") {
            Some((r, j)) => (r.trim(), j.trim()),
            None => (rest, ""),
        };
        // Directive suppresses its own line when it trails code,
        // otherwise the next line that carries code.
        let target_line = if !line.code.trim().is_empty() {
            line.number
        } else {
            lines[i + 1..]
                .iter()
                .find(|l| !l.code.trim().is_empty())
                .map(|l| l.number)
                .unwrap_or(line.number)
        };
        out.push(Allow {
            rule: rule.to_string(),
            justification: justification.to_string(),
            target_line,
            line: line.number,
        });
    }
    out
}

/// Split one raw line into (code, comment) given the carried-in state;
/// returns the state carried out to the next line.
fn split_line(raw: &str, mut state: State) -> (String, String, State) {
    let b: Vec<char> = raw.chars().collect();
    let mut code = String::new();
    let mut comment = String::new();
    let mut i = 0usize;
    while i < b.len() {
        match state {
            State::Block(d) => {
                if b[i] == '*' && i + 1 < b.len() && b[i + 1] == '/' {
                    state = if d > 1 { State::Block(d - 1) } else { State::Normal };
                    i += 2;
                } else if b[i] == '/' && i + 1 < b.len() && b[i + 1] == '*' {
                    state = State::Block(d + 1);
                    i += 2;
                } else {
                    comment.push(b[i]);
                    i += 1;
                }
            }
            State::Str => {
                if b[i] == '\\' {
                    i += 2; // escape: skip the escaped char (may run past EOL)
                } else if b[i] == '"' {
                    code.push('"');
                    state = State::Normal;
                    i += 1;
                } else {
                    i += 1; // literal contents blanked
                }
            }
            State::RawStr(hashes) => {
                if b[i] == '"' {
                    let n = hashes as usize;
                    let tail: String =
                        b[i + 1..(i + 1 + n).min(b.len())].iter().collect();
                    if tail.chars().filter(|&c| c == '#').count() == n
                        && tail.len() == n
                    {
                        code.push('"');
                        state = State::Normal;
                        i += 1 + n;
                        continue;
                    }
                }
                i += 1;
            }
            State::Normal => {
                let c = b[i];
                if c == '/' && i + 1 < b.len() && b[i + 1] == '/' {
                    // line comment: rest of line
                    comment.push_str(&b[i + 2..].iter().collect::<String>());
                    i = b.len();
                } else if c == '/' && i + 1 < b.len() && b[i + 1] == '*' {
                    state = State::Block(1);
                    i += 2;
                } else if c == '"' {
                    code.push('"');
                    state = State::Str;
                    i += 1;
                } else if c == 'r'
                    && !prev_is_ident(&b, i)
                    && raw_str_hashes(&b, i + 1).is_some()
                {
                    let h = raw_str_hashes(&b, i + 1).unwrap();
                    code.push('"');
                    state = State::RawStr(h);
                    i += 2 + h as usize; // r + hashes + quote
                } else if c == 'b'
                    && !prev_is_ident(&b, i)
                    && i + 1 < b.len()
                    && b[i + 1] == '"'
                {
                    code.push('"');
                    state = State::Str;
                    i += 2;
                } else if c == '\'' {
                    // char literal vs lifetime
                    if i + 1 < b.len() && b[i + 1] == '\\' {
                        // escaped char literal: skip to closing quote
                        let mut j = i + 2;
                        while j < b.len() && b[j] != '\'' {
                            j += 1;
                        }
                        code.push('\'');
                        code.push('\'');
                        i = j + 1;
                    } else if i + 2 < b.len() && b[i + 2] == '\'' {
                        // one-char literal 'x'
                        code.push('\'');
                        code.push('\'');
                        i += 3;
                    } else {
                        // lifetime (or stray quote): keep as code
                        code.push('\'');
                        i += 1;
                    }
                } else {
                    code.push(c);
                    i += 1;
                }
            }
        }
    }
    // Str/RawStr/Block all legitimately span lines in Rust (multi-line
    // string literals like the USAGE const rely on this) — carry the
    // state through.
    (code, comment, state)
}

fn prev_is_ident(b: &[char], i: usize) -> bool {
    i > 0 && (b[i - 1].is_alphanumeric() || b[i - 1] == '_')
}

/// If `b[from..]` is `#*"` (a raw-string opener after `r`), return the
/// number of hashes.
fn raw_str_hashes(b: &[char], from: usize) -> Option<u32> {
    let mut j = from;
    let mut h = 0u32;
    while j < b.len() && b[j] == '#' {
        h += 1;
        j += 1;
    }
    if j < b.len() && b[j] == '"' {
        Some(h)
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strips_comments_and_strings() {
        let f = scan("let x = \"panic!()\"; // .unwrap() here\n");
        assert!(!f.lines[0].code.contains("panic"));
        assert!(f.lines[0].comment.contains(".unwrap()"));
    }

    #[test]
    fn block_comments_span_lines() {
        let f = scan("/* a\n .unwrap() b */ let y = 1;\n");
        assert!(!f.lines[1].code.contains("unwrap"));
        assert!(f.lines[1].code.contains("let y"));
    }

    #[test]
    fn cfg_test_region_tracked() {
        let src = "fn real() {}\n#[cfg(test)]\nmod tests {\n    fn t() { x.unwrap(); }\n}\nfn after() {}\n";
        let f = scan(src);
        assert!(!f.lines[0].in_test);
        assert!(f.lines[3].in_test);
        assert!(!f.lines[5].in_test);
    }

    #[test]
    fn allow_targets_next_code_line() {
        let src = "// xtask-allow: no-raw-instant -- timing harness\nlet t = Instant::now();\n";
        let f = scan(src);
        assert_eq!(f.allows.len(), 1);
        assert_eq!(f.allows[0].rule, "no-raw-instant");
        assert_eq!(f.allows[0].target_line, 2);
        assert!(!f.allows[0].justification.is_empty());
    }

    #[test]
    fn trailing_allow_targets_own_line() {
        let src = "let t = Instant::now(); // xtask-allow: no-raw-instant -- poll deadline\n";
        let f = scan(src);
        assert_eq!(f.allows[0].target_line, 1);
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let f = scan("fn f<'a>(x: &'a str) -> &'a str { x }\n");
        assert!(f.lines[0].code.contains("fn f<'a>"));
    }

    #[test]
    fn raw_strings_blanked() {
        let f = scan("let s = r#\"contains .unwrap() text\"#;\n");
        assert!(!f.lines[0].code.contains("unwrap"));
    }
}
