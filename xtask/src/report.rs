//! Machine-readable output for `xtask analyze` — a hand-rolled JSON
//! writer (std-only; the report shape is small and fixed, so a
//! serialization dependency would be pure weight).

/// One rule violation.
#[derive(Clone, Debug)]
pub struct Finding {
    /// Rule name (see [`crate::rules::RULES`]).
    pub rule: String,
    /// Path relative to the repo root.
    pub file: String,
    /// 1-based line (0 for file-level findings like drift checks).
    pub line: usize,
    /// Human-readable description with the fix direction.
    pub message: String,
}

/// One suppressed finding (an `xtask-allow` that matched).
#[derive(Clone, Debug)]
pub struct Suppressed {
    /// Rule name the allow suppressed.
    pub rule: String,
    /// Path relative to the repo root.
    pub file: String,
    /// Line the allow targeted.
    pub line: usize,
    /// The justification text from the directive.
    pub justification: String,
}

/// Full analyzer output.
#[derive(Clone, Debug, Default)]
pub struct Report {
    /// Violations that fail the run.
    pub findings: Vec<Finding>,
    /// Findings suppressed by justified `xtask-allow` directives.
    pub suppressed: Vec<Suppressed>,
    /// Number of `.rs` files scanned.
    pub files_scanned: usize,
}

impl Report {
    /// True when the tree is clean (analyze exits 0).
    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// Render as JSON (stable field order, findings in discovery order).
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n");
        out.push_str(&format!(
            "  \"files_scanned\": {},\n  \"finding_count\": {},\n",
            self.files_scanned,
            self.findings.len()
        ));
        out.push_str("  \"findings\": [\n");
        for (i, f) in self.findings.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"rule\": {}, \"file\": {}, \"line\": {}, \"message\": {}}}{}\n",
                json_str(&f.rule),
                json_str(&f.file),
                f.line,
                json_str(&f.message),
                if i + 1 < self.findings.len() { "," } else { "" }
            ));
        }
        out.push_str("  ],\n  \"suppressed\": [\n");
        for (i, s) in self.suppressed.iter().enumerate() {
            out.push_str(&format!(
                "    {{\"rule\": {}, \"file\": {}, \"line\": {}, \"justification\": {}}}{}\n",
                json_str(&s.rule),
                json_str(&s.file),
                s.line,
                json_str(&s.justification),
                if i + 1 < self.suppressed.len() { "," } else { "" }
            ));
        }
        out.push_str("  ]\n}\n");
        out
    }
}

/// JSON string literal with escaping.
fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32))
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_escapes() {
        assert_eq!(json_str("a\"b\\c\nd"), "\"a\\\"b\\\\c\\nd\"");
    }

    #[test]
    fn report_renders() {
        let mut r = Report { files_scanned: 2, ..Report::default() };
        r.findings.push(Finding {
            rule: "no-panic-hot-path".into(),
            file: "rust/src/x.rs".into(),
            line: 3,
            message: "found .unwrap()".into(),
        });
        let j = r.to_json();
        assert!(j.contains("\"finding_count\": 1"));
        assert!(j.contains("no-panic-hot-path"));
        assert!(!r.clean());
    }
}
