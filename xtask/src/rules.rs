//! The rule registry and the repo invariants.
//!
//! Every rule is documented in ARCHITECTURE.md §Analysis gauntlet; the
//! one-line `invariant` strings here are what `analyze` prints next to a
//! violation so the fix direction is always in the output.

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

use crate::lexer::{self, ScannedFile};
use crate::report::{Finding, Report, Suppressed};

/// Registry entry: rule name + the invariant it guards.
pub struct RuleInfo {
    /// Stable rule name (used in `xtask-allow: <name>` directives).
    pub name: &'static str,
    /// One-line statement of the guarded invariant.
    pub invariant: &'static str,
}

/// All rules, in severity-ish order. `allow-hygiene` is the meta-rule
/// keeping the escape hatch honest (justifications required, stale
/// directives flagged).
pub const RULES: &[RuleInfo] = &[
    RuleInfo {
        name: "no-panic-hot-path",
        invariant: "serving and hot-path modules (serve.rs, stream.rs, \
                    coordinator/fabric/, parallel/, greedy.rs, cli/, \
                    main.rs, data/storage.rs) must not call \
                    .unwrap()/.expect()/panic! outside tests — propagate \
                    Results or recover (PoisonError::into_inner, \
                    resume_unwind)",
    },
    RuleInfo {
        name: "no-raw-instant",
        invariant: "Instant::now() belongs to the session clock \
                    (select/session.rs) — raw clock reads elsewhere \
                    caused the PR 4 TimeBudget reset bug; measurement \
                    sites need a justified xtask-allow",
    },
    RuleInfo {
        name: "config-via-builder",
        invariant: "SelectionConfig is constructed through its builder \
                    (or re-opened with .with()) so new fields pick up \
                    defaults everywhere at once — no struct literals \
                    outside select/mod.rs",
    },
    RuleInfo {
        name: "serial-float-reduction",
        invariant: "closures handed to par_map/map_ranges must not \
                    accumulate floats (+=, .sum(), fold, .product()) — \
                    reductions run on the calling thread in serial order \
                    or the bit-identical-at-any-thread-count guarantee \
                    breaks",
    },
    RuleInfo {
        name: "scan-via-kernel",
        invariant: "select/ and data/storage.rs must route O(mn) \
                    multiply-accumulate inner loops through the kernel \
                    tier (crate::kernel) — raw `x += a * b` loops dodge \
                    the SIMD/precision dispatch; quadratic reference \
                    baselines need a justified xtask-allow",
    },
    RuleInfo {
        name: "usage-drift",
        invariant: "README.md §CLI reference and cli/mod.rs USAGE must \
                    agree on the command and flag inventory",
    },
    RuleInfo {
        name: "checkpoint-format-pin",
        invariant: "checkpoint.rs (non-test) is hash-pinned against \
                    FORMAT_VERSION: serialization changes must bump the \
                    version; refresh with `cargo run -p xtask -- pin`",
    },
    RuleInfo {
        name: "no-unbounded-io",
        invariant: "fabric/serve socket code must never block without a \
                    deadline: no TcpStream::connect (connect_timeout \
                    instead), no read_to_end/read_to_string, no \
                    set_read_timeout(None); a file that connects must \
                    also arm read timeouts. data/storage.rs additionally \
                    must never slurp whole files: no \
                    read_to_end/read_to_string — stream fixed-size chunks",
    },
    RuleInfo {
        name: "allow-hygiene",
        invariant: "xtask-allow directives need a `-- justification` and \
                    must still match a finding (stale allows are removed, \
                    not accumulated)",
    },
];

/// Relative path of the pin file guarding rule `checkpoint-format-pin`.
pub const PIN_FILE: &str = "xtask/checkpoint_format.pin";
const CHECKPOINT_RS: &str = "rust/src/select/checkpoint.rs";
const CLI_MOD_RS: &str = "rust/src/cli/mod.rs";

/// Run every rule over `root` and resolve allow directives.
pub fn analyze(root: &Path) -> io::Result<Report> {
    let mut files = Vec::new();
    collect_rs(&root.join("rust").join("src"), &mut files)?;
    files.sort();

    let mut scans: Vec<(String, String, ScannedFile)> = Vec::new();
    for path in &files {
        let rel = rel_path(root, path);
        let contents = fs::read_to_string(path)?;
        let scanned = lexer::scan(&contents);
        scans.push((rel, contents, scanned));
    }

    let mut raw: Vec<Finding> = Vec::new();
    for (rel, _contents, scanned) in &scans {
        token_rules(rel, scanned, &mut raw);
        float_reduction(rel, scanned, &mut raw);
        unbounded_io(rel, scanned, &mut raw);
        scan_via_kernel(rel, scanned, &mut raw);
    }
    usage_drift(root, &mut raw)?;
    checkpoint_pin(root, &mut raw)?;

    let mut report = Report {
        files_scanned: scans.len(),
        ..Report::default()
    };
    resolve_allows(&scans, raw, &mut report);
    Ok(report)
}

/// Recompute the checkpoint-format pin file contents for `root`.
pub fn pin_contents(root: &Path) -> io::Result<String> {
    let (version, hash) = checkpoint_fingerprint(root)?;
    Ok(format!(
        "# Pin guarding rule `checkpoint-format-pin`: the FNV-1a hash of\n\
         # rust/src/select/checkpoint.rs (test modules excluded) at the\n\
         # last reviewed FORMAT_VERSION. A hash change without a version\n\
         # bump means serialization may have drifted silently; refresh\n\
         # with `cargo run -p xtask -- pin` after review.\n\
         format_version = {version}\n\
         source_hash = fnv1a64:{hash:016x}\n"
    ))
}

/// Write the pin file under `root`; returns its relative path.
pub fn write_pin(root: &Path) -> io::Result<String> {
    fs::write(root.join(PIN_FILE), pin_contents(root)?)?;
    Ok(PIN_FILE.to_string())
}

// ---------------------------------------------------------------------
// per-line token rules (1-3)

fn is_hot_path(rel: &str) -> bool {
    rel == "rust/src/main.rs"
        || rel.starts_with("rust/src/cli/")
        || rel.starts_with("rust/src/parallel/")
        || rel == "rust/src/coordinator/serve.rs"
        || rel == "rust/src/coordinator/stream.rs"
        || rel.starts_with("rust/src/coordinator/fabric/")
        || rel == "rust/src/select/greedy.rs"
        || rel == "rust/src/data/storage.rs"
}

fn token_rules(rel: &str, f: &ScannedFile, out: &mut Vec<Finding>) {
    let hot = is_hot_path(rel);
    for line in &f.lines {
        if line.in_test {
            continue;
        }
        let code = line.code.as_str();
        if hot {
            for tok in [".unwrap()", ".expect(", "panic!"] {
                if code.contains(tok) {
                    out.push(Finding {
                        rule: "no-panic-hot-path".into(),
                        file: rel.into(),
                        line: line.number,
                        message: format!(
                            "`{tok}` in a serving/hot-path module — \
                             propagate the error or recover instead of \
                             aborting a worker"
                        ),
                    });
                }
            }
        }
        if rel != "rust/src/select/session.rs"
            && code.contains("Instant::now")
        {
            out.push(Finding {
                rule: "no-raw-instant".into(),
                file: rel.into(),
                line: line.number,
                message: "raw `Instant::now()` outside the session clock \
                          (select/session.rs) — route timing through the \
                          session, or justify the measurement site with \
                          an xtask-allow"
                    .into(),
            });
        }
        if rel != "rust/src/select/mod.rs" && has_config_literal(code) {
            out.push(Finding {
                rule: "config-via-builder".into(),
                file: rel.into(),
                line: line.number,
                message: "`SelectionConfig { … }` struct literal bypasses \
                          the builder — use SelectionConfig::builder() or \
                          cfg.with() so new fields default correctly"
                    .into(),
            });
        }
    }
}

fn has_config_literal(code: &str) -> bool {
    let mut search = 0usize;
    while let Some(p) = code[search..].find("SelectionConfig") {
        let after = search + p + "SelectionConfig".len();
        let rest = code[after..].trim_start();
        if rest.starts_with('{') {
            return true;
        }
        search = after;
    }
    false
}

// ---------------------------------------------------------------------
// rule 4: serial-float-reduction

const PAR_CALLS: [&str; 2] = ["par_map(", "map_ranges("];
const REDUCTION_TOKENS: [&str; 5] =
    ["+=", ".sum()", ".sum::<", ".fold(", ".product()"];

fn float_reduction(rel: &str, f: &ScannedFile, out: &mut Vec<Finding>) {
    for i in 0..f.lines.len() {
        if f.lines[i].in_test {
            continue;
        }
        let code = &f.lines[i].code;
        let mut from = 0usize;
        while let Some(open) = find_par_call(code, from) {
            scan_call_extent(rel, f, i, open, out);
            from = open;
        }
    }
}

/// Byte offset just past the `(` of the next par_map/map_ranges call at
/// or after `from`, if any.
fn find_par_call(code: &str, from: usize) -> Option<usize> {
    let mut best: Option<usize> = None;
    for pat in PAR_CALLS {
        if let Some(p) = code[from..].find(pat) {
            let end = from + p + pat.len();
            best = Some(best.map_or(end, |b: usize| b.min(end)));
        }
    }
    best
}

/// Walk the balanced-paren extent starting just inside the call's `(`
/// and flag float-reduction tokens found inside it.
fn scan_call_extent(
    rel: &str,
    f: &ScannedFile,
    start_line: usize,
    start_off: usize,
    out: &mut Vec<Finding>,
) {
    let mut depth = 1i32;
    let mut li = start_line;
    while depth > 0 && li < f.lines.len() {
        let code = &f.lines[li].code;
        let begin = if li == start_line { start_off } else { 0 };
        let bytes = code.as_bytes();
        let mut end = bytes.len();
        for (j, &b) in bytes.iter().enumerate().skip(begin) {
            match b {
                b'(' => depth += 1,
                b')' => {
                    depth -= 1;
                    if depth == 0 {
                        end = j;
                        break;
                    }
                }
                _ => {}
            }
        }
        let seg = &code[begin..end];
        for tok in REDUCTION_TOKENS {
            if seg.contains(tok) {
                out.push(Finding {
                    rule: "serial-float-reduction".into(),
                    file: rel.into(),
                    line: f.lines[li].number,
                    message: format!(
                        "`{tok}` inside a par_map/map_ranges call extent — \
                         shard-local accumulation must move to the \
                         calling-thread serial reduction or determinism \
                         across thread counts breaks"
                    ),
                });
            }
        }
        li += 1;
    }
}

// ---------------------------------------------------------------------
// rule: no-unbounded-io

/// Socket-touching modules covered by `no-unbounded-io` — the serving
/// fabric plus the serve module its followers plug into.
fn is_fabric_io(rel: &str) -> bool {
    rel.starts_with("rust/src/coordinator/fabric/")
        || rel == "rust/src/coordinator/serve.rs"
}

/// `(token, message)` pairs flagged line-by-line in fabric/serve code.
const UNBOUNDED_IO_TOKENS: [(&str, &str); 5] = [
    (
        "TcpStream::connect(",
        "`TcpStream::connect` blocks without a deadline — use \
         `TcpStream::connect_timeout`",
    ),
    (
        "UnixStream::connect(",
        "unix connect has no deadline in std — arm read/write timeouts \
         immediately after and justify the connect with an xtask-allow",
    ),
    (
        ".read_to_end(",
        "unbounded socket read — frame reads must be length-prefixed \
         and validated before allocation",
    ),
    (
        ".read_to_string(",
        "unbounded socket read — frame reads must be length-prefixed \
         and validated before allocation",
    ),
    (
        "set_read_timeout(None",
        "disabling the read deadline lets a silent peer hang this \
         worker forever",
    ),
];

/// Out-of-core storage module covered by the bounded-read half of
/// `no-unbounded-io` — the streaming loader refills fixed-size chunks
/// so memory stays capped regardless of file size; a whole-file slurp
/// silently reintroduces the O(file) allocation the backend exists to
/// avoid. Socket pairing checks do not apply here.
fn is_storage_io(rel: &str) -> bool {
    rel == "rust/src/data/storage.rs"
}

/// `(token, message)` pairs flagged line-by-line in storage code.
const STORAGE_IO_TOKENS: [(&str, &str); 2] = [
    (
        ".read_to_end(",
        "unbounded file read in the storage layer — stream through \
         fixed-size chunk refills so memory stays capped at the \
         configured chunk/window size",
    ),
    (
        ".read_to_string(",
        "unbounded file read in the storage layer — stream through \
         fixed-size chunk refills so memory stays capped at the \
         configured chunk/window size",
    ),
];

/// Flag blocking socket calls without deadlines in fabric/serve code,
/// plus a file-level pairing check: a file that opens connections must
/// also arm read timeouts somewhere (file-level findings carry line 0
/// and cannot be allowed away — fix the file). In data/storage.rs only
/// the whole-file-read tokens apply.
fn unbounded_io(rel: &str, f: &ScannedFile, out: &mut Vec<Finding>) {
    if is_storage_io(rel) {
        for line in &f.lines {
            if line.in_test {
                continue;
            }
            let code = line.code.as_str();
            for (tok, why) in STORAGE_IO_TOKENS {
                if code.contains(tok) {
                    out.push(Finding {
                        rule: "no-unbounded-io".into(),
                        file: rel.into(),
                        line: line.number,
                        message: why.to_string(),
                    });
                }
            }
        }
        return;
    }
    if !is_fabric_io(rel) {
        return;
    }
    let mut connects = false;
    let mut arms_read_timeout = false;
    for line in &f.lines {
        if line.in_test {
            continue;
        }
        let code = line.code.as_str();
        for (tok, why) in UNBOUNDED_IO_TOKENS {
            if code.contains(tok) {
                out.push(Finding {
                    rule: "no-unbounded-io".into(),
                    file: rel.into(),
                    line: line.number,
                    message: why.to_string(),
                });
            }
        }
        if code.contains("TcpStream::connect_timeout(")
            || code.contains("UnixStream::connect(")
        {
            connects = true;
        }
        if code.contains("set_read_timeout(") {
            arms_read_timeout = true;
        }
    }
    if connects && !arms_read_timeout {
        out.push(Finding {
            rule: "no-unbounded-io".into(),
            file: rel.into(),
            line: 0,
            message: "this file opens socket connections but never arms \
                      a read timeout (`set_read_timeout`) — a silent \
                      peer would block its readers forever"
                .into(),
        });
    }
}

// ---------------------------------------------------------------------
// rule: scan-via-kernel

/// Modules whose O(mn) inner loops must live in the kernel tier: the
/// selector layer and the out-of-core storage scans. `kernel/` itself
/// and `parallel/` (which only shards and delegates) are out of scope.
fn is_kernel_scope(rel: &str) -> bool {
    rel.starts_with("rust/src/select/") || rel == "rust/src/data/storage.rs"
}

/// A raw multiply-accumulate: a `+=`/`-=` compound assignment with a
/// `*` anywhere after it on the same line — the shape of every
/// hand-rolled dot-product/axpy inner loop. Plain accumulation
/// (`acc += v`) and integer bookkeeping without a multiply are fine.
fn has_raw_axpy(code: &str) -> bool {
    for op in ["+=", "-="] {
        if let Some(p) = code.find(op) {
            if code[p + op.len()..].contains('*') {
                return true;
            }
        }
    }
    false
}

/// Flag hand-rolled multiply-accumulate loops in selector/storage code —
/// they bypass the kernel tier's single dispatch point, so a SIMD or
/// mixed-precision build would silently run them scalar-f64 and the
/// per-(kernel, precision) bit-identity contract loses its meaning.
/// Quadratic reference baselines (faithful to the paper's O(m²)
/// algorithms, deliberately not on the hot path) justify an xtask-allow.
fn scan_via_kernel(rel: &str, f: &ScannedFile, out: &mut Vec<Finding>) {
    if !is_kernel_scope(rel) {
        return;
    }
    for line in &f.lines {
        if line.in_test {
            continue;
        }
        if has_raw_axpy(line.code.as_str()) {
            out.push(Finding {
                rule: "scan-via-kernel".into(),
                file: rel.into(),
                line: line.number,
                message: "raw multiply-accumulate loop in selector/storage \
                          code — route the inner loop through \
                          crate::kernel so SIMD and mixed-precision \
                          dispatch stay centralized, or justify a \
                          quadratic baseline with an xtask-allow"
                    .into(),
            });
        }
    }
}

// ---------------------------------------------------------------------
// rule 5: usage-drift

fn usage_drift(root: &Path, out: &mut Vec<Finding>) -> io::Result<()> {
    let cli = fs::read_to_string(root.join(CLI_MOD_RS))?;
    let readme = fs::read_to_string(root.join("README.md"))?;

    let Some(usage) = extract_usage_const(&cli) else {
        out.push(Finding {
            rule: "usage-drift".into(),
            file: CLI_MOD_RS.into(),
            line: 0,
            message: "could not locate `pub const USAGE: &str` — the \
                      drift check needs the canonical usage text"
                .into(),
        });
        return Ok(());
    };
    let Some(section) = extract_readme_section(&readme, "## CLI reference")
    else {
        out.push(Finding {
            rule: "usage-drift".into(),
            file: "README.md".into(),
            line: 0,
            message: "README.md has no `## CLI reference` section to sync \
                      against cli/mod.rs USAGE"
                .into(),
        });
        return Ok(());
    };

    let usage_cmds = usage_commands(&usage);
    let readme_cmds = readme_commands(&section);
    let usage_flags = flag_tokens(&usage);
    let readme_flags = flag_tokens(&section);

    diff_sets(
        out,
        "command",
        &usage_cmds,
        &readme_cmds,
        "cli/mod.rs USAGE",
        "README.md §CLI reference",
    );
    diff_sets(
        out,
        "flag",
        &usage_flags,
        &readme_flags,
        "cli/mod.rs USAGE",
        "README.md §CLI reference",
    );
    Ok(())
}

fn diff_sets(
    out: &mut Vec<Finding>,
    kind: &str,
    usage: &[String],
    readme: &[String],
    usage_name: &str,
    readme_name: &str,
) {
    for item in usage {
        if !readme.contains(item) {
            out.push(Finding {
                rule: "usage-drift".into(),
                file: "README.md".into(),
                line: 0,
                message: format!(
                    "{kind} `{item}` is in {usage_name} but missing from \
                     {readme_name}"
                ),
            });
        }
    }
    for item in readme {
        if !usage.contains(item) {
            out.push(Finding {
                rule: "usage-drift".into(),
                file: "README.md".into(),
                line: 0,
                message: format!(
                    "{kind} `{item}` is in {readme_name} but not in \
                     {usage_name} — stale doc or missing usage entry"
                ),
            });
        }
    }
}

/// The USAGE string literal's text (escapes left as-is; the inventory
/// scans below only need command tokens and `--flag` shapes).
fn extract_usage_const(cli_src: &str) -> Option<String> {
    let start = cli_src.find("pub const USAGE: &str = \"")?;
    let body_start = start + "pub const USAGE: &str = \"".len();
    let end = cli_src[body_start..].find("\n\";")?;
    Some(cli_src[body_start..body_start + end].to_string())
}

/// Command tokens: USAGE lines indented exactly two spaces.
fn usage_commands(usage: &str) -> Vec<String> {
    let mut out = Vec::new();
    for line in usage.lines() {
        let Some(rest) = line.strip_prefix("  ") else { continue };
        if rest.starts_with(' ') {
            continue; // continuation line
        }
        if let Some(tok) = rest.split_whitespace().next() {
            let tok = tok.to_string();
            if !out.contains(&tok) {
                out.push(tok);
            }
        }
    }
    out
}

/// Command tokens: first word of backticked first cells in the README
/// section's tables (`| \`serve --follow DIR\` | …` yields `serve`).
fn readme_commands(section: &str) -> Vec<String> {
    let mut out = Vec::new();
    for line in section.lines() {
        let t = line.trim();
        let Some(rest) = t.strip_prefix("| `") else { continue };
        let Some(cell_end) = rest.find('`') else { continue };
        if let Some(tok) = rest[..cell_end].split_whitespace().next() {
            let tok = tok.to_string();
            if !out.contains(&tok) {
                out.push(tok);
            }
        }
    }
    out
}

/// Every `--flag` token in `text` (first char after `--` must be a-z;
/// the preceding char must not be part of a longer token).
fn flag_tokens(text: &str) -> Vec<String> {
    let b = text.as_bytes();
    let mut out: Vec<String> = Vec::new();
    let mut i = 0usize;
    while i + 2 < b.len() {
        if b[i] == b'-'
            && b[i + 1] == b'-'
            && b[i + 2].is_ascii_lowercase()
            && (i == 0 || !is_flag_char(b[i - 1]) && b[i - 1] != b'-')
        {
            let mut j = i + 2;
            while j < b.len() && is_flag_char(b[j]) {
                j += 1;
            }
            let tok: String =
                text[i + 2..j].trim_end_matches('-').to_string();
            if !out.contains(&tok) {
                out.push(tok);
            }
            i = j;
        } else {
            i += 1;
        }
    }
    out.sort();
    out
}

fn is_flag_char(b: u8) -> bool {
    b.is_ascii_lowercase() || b.is_ascii_digit() || b == b'-'
}

/// Section text from `heading` to the next `## ` heading (exclusive).
fn extract_readme_section(readme: &str, heading: &str) -> Option<String> {
    let mut in_section = false;
    let mut out = String::new();
    for line in readme.lines() {
        if line.trim_end() == heading {
            in_section = true;
            continue;
        }
        if in_section && line.starts_with("## ") {
            break;
        }
        if in_section {
            out.push_str(line);
            out.push('\n');
        }
    }
    if in_section {
        Some(out)
    } else {
        None
    }
}

// ---------------------------------------------------------------------
// rule 6: checkpoint-format-pin

/// (FORMAT_VERSION, FNV-1a-64 of the non-test lines of checkpoint.rs).
pub fn checkpoint_fingerprint(root: &Path) -> io::Result<(u32, u64)> {
    let contents = fs::read_to_string(root.join(CHECKPOINT_RS))?;
    let version = parse_format_version(&contents).ok_or_else(|| {
        io::Error::new(
            io::ErrorKind::InvalidData,
            "FORMAT_VERSION constant not found in checkpoint.rs",
        )
    })?;
    let scanned = lexer::scan(&contents);
    let mut hash: u64 = 0xcbf2_9ce4_8422_2325;
    for (raw, line) in contents.lines().zip(&scanned.lines) {
        if line.in_test {
            continue;
        }
        for &b in raw.as_bytes() {
            hash ^= b as u64;
            hash = hash.wrapping_mul(0x0000_0100_0000_01b3);
        }
        hash ^= b'\n' as u64;
        hash = hash.wrapping_mul(0x0000_0100_0000_01b3);
    }
    Ok((version, hash))
}

fn parse_format_version(contents: &str) -> Option<u32> {
    let p = contents.find("FORMAT_VERSION: u32 =")?;
    let rest = contents[p + "FORMAT_VERSION: u32 =".len()..].trim_start();
    let digits: String =
        rest.chars().take_while(|c| c.is_ascii_digit()).collect();
    digits.parse().ok()
}

fn checkpoint_pin(root: &Path, out: &mut Vec<Finding>) -> io::Result<()> {
    let (version, hash) = checkpoint_fingerprint(root)?;
    let pin_path = root.join(PIN_FILE);
    let Ok(pin) = fs::read_to_string(&pin_path) else {
        out.push(Finding {
            rule: "checkpoint-format-pin".into(),
            file: PIN_FILE.into(),
            line: 0,
            message: "pin file missing — run `cargo run -p xtask -- pin` \
                      and commit it"
                .into(),
        });
        return Ok(());
    };
    let pinned_version = pin_field(&pin, "format_version")
        .and_then(|v| v.parse::<u32>().ok());
    let pinned_hash = pin_field(&pin, "source_hash")
        .and_then(|v| v.strip_prefix("fnv1a64:").map(str::to_string))
        .and_then(|v| u64::from_str_radix(&v, 16).ok());
    match (pinned_version, pinned_hash) {
        (Some(pv), Some(ph)) => {
            if pv != version {
                out.push(Finding {
                    rule: "checkpoint-format-pin".into(),
                    file: PIN_FILE.into(),
                    line: 0,
                    message: format!(
                        "pin is stale (FORMAT_VERSION {pv} pinned, {version} \
                         in checkpoint.rs) — run `cargo run -p xtask -- pin` \
                         in the same change"
                    ),
                });
            } else if ph != hash {
                out.push(Finding {
                    rule: "checkpoint-format-pin".into(),
                    file: CHECKPOINT_RS.into(),
                    line: 0,
                    message: format!(
                        "checkpoint.rs (non-test) changed but FORMAT_VERSION \
                         is still {version} — bump it if the serialized \
                         format changed; otherwise re-pin with `cargo run \
                         -p xtask -- pin` to attest it did not"
                    ),
                });
            }
        }
        _ => out.push(Finding {
            rule: "checkpoint-format-pin".into(),
            file: PIN_FILE.into(),
            line: 0,
            message: "pin file is malformed — regenerate with `cargo run \
                      -p xtask -- pin`"
                .into(),
        }),
    }
    Ok(())
}

fn pin_field(pin: &str, key: &str) -> Option<String> {
    for line in pin.lines() {
        let t = line.trim();
        if let Some(rest) = t.strip_prefix(key) {
            let rest = rest.trim_start();
            if let Some(v) = rest.strip_prefix('=') {
                return Some(v.trim().to_string());
            }
        }
    }
    None
}

// ---------------------------------------------------------------------
// allow resolution

fn resolve_allows(
    scans: &[(String, String, ScannedFile)],
    raw: Vec<Finding>,
    report: &mut Report,
) {
    // (file, rule, target_line) -> (allow, used)
    let mut allows: Vec<(String, lexer::Allow, bool)> = Vec::new();
    for (rel, _contents, scanned) in scans {
        for a in &scanned.allows {
            allows.push((rel.clone(), a.clone(), false));
        }
    }

    for finding in raw {
        let hit = allows.iter_mut().find(|(file, a, _)| {
            *file == finding.file
                && a.rule == finding.rule
                && a.target_line == finding.line
        });
        match hit {
            Some((file, a, used)) if !a.justification.is_empty() => {
                *used = true;
                report.suppressed.push(Suppressed {
                    rule: a.rule.clone(),
                    file: file.clone(),
                    line: a.target_line,
                    justification: a.justification.clone(),
                });
            }
            Some((_, a, used)) => {
                // matched but unjustified: the finding stands, plus a
                // hygiene finding pointing at the directive
                *used = true;
                report.findings.push(Finding {
                    rule: "allow-hygiene".into(),
                    file: finding.file.clone(),
                    line: a.line,
                    message: format!(
                        "xtask-allow for `{}` has no `-- justification`",
                        a.rule
                    ),
                });
                report.findings.push(finding);
            }
            None => report.findings.push(finding),
        }
    }

    for (file, a, used) in &allows {
        if !used {
            report.findings.push(Finding {
                rule: "allow-hygiene".into(),
                file: file.clone(),
                line: a.line,
                message: format!(
                    "stale xtask-allow: no `{}` finding targets line {} — \
                     remove the directive",
                    a.rule, a.target_line
                ),
            });
        }
    }
}

// ---------------------------------------------------------------------
// fs helpers

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

fn rel_path(root: &Path, path: &Path) -> String {
    path.strip_prefix(root)
        .unwrap_or(path)
        .to_string_lossy()
        .replace('\\', "/")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_literal_detected() {
        assert!(has_config_literal("let c = SelectionConfig { k: 1 };"));
        assert!(has_config_literal("SelectionConfig{k:1}"));
        assert!(!has_config_literal("SelectionConfig::builder().build()"));
        assert!(!has_config_literal("fn f(c: &SelectionConfig) {}"));
    }

    #[test]
    fn raw_axpy_detected() {
        assert!(has_raw_axpy("s += a[j] * b[j];"));
        assert!(has_raw_axpy("*c_ -= f * gvc;"));
        assert!(has_raw_axpy("acc += x * y"));
        assert!(!has_raw_axpy("i += 1;"));
        assert!(!has_raw_axpy("acc += v;"));
        assert!(!has_raw_axpy("let p = a * b;"));
        assert!(!has_raw_axpy("*fj += wv;"));
    }

    #[test]
    fn kernel_scope_paths() {
        assert!(is_kernel_scope("rust/src/select/greedy.rs"));
        assert!(is_kernel_scope("rust/src/select/sketch.rs"));
        assert!(is_kernel_scope("rust/src/data/storage.rs"));
        assert!(!is_kernel_scope("rust/src/kernel/scalar.rs"));
        assert!(!is_kernel_scope("rust/src/parallel/mod.rs"));
    }

    #[test]
    fn flag_tokens_extract() {
        let f = flag_tokens("use --k K and --time-budget-s S, not ---x |---|");
        assert_eq!(f, vec!["k", "time-budget-s"]);
    }

    #[test]
    fn usage_command_lines() {
        let u = "HEAD\n  select     do things\n             --k K\n  cv         other\n\nfooter at col 0\n";
        assert_eq!(usage_commands(u), vec!["select", "cv"]);
    }

    #[test]
    fn readme_command_cells() {
        let s = "| command | purpose |\n|---|---|\n| `select` | x |\n| `serve --follow DIR` | y |\n| plain | z |\n";
        assert_eq!(readme_commands(s), vec!["select", "serve"]);
    }

    #[test]
    fn format_version_parses() {
        assert_eq!(
            parse_format_version("pub const FORMAT_VERSION: u32 = 7;"),
            Some(7)
        );
    }
}
